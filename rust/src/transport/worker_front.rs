//! Front-side worker service: the half of the remote worker plane that
//! lives in the trainer process.
//!
//! With `[cluster] workers = "remote"` the session's Algorithm-1 loops
//! run in separate `gba-train worker` OS processes. The front binds one
//! listening socket ([`WorkerFront::bind`]), waits for `mode.workers`
//! connect-time `Hello` identity/shape handshakes
//! ([`WorkerFront::ensure_connected`]), and then serves every worker's
//! day on **one event-loop thread** ([`WorkerFront::run_day`]): each
//! connection is a nonblocking [`BufConn`], and a readiness sweep
//! drains queued replies, retries gated pulls, and executes
//! `Pull`/`Push`/`Gather`/`DenseParams`/`Reset` requests against the
//! shared PS front — the token-control plane is driven *unchanged*, by
//! the same five verbs the in-thread workers call — before collecting
//! the `EndOfDay` stats. A 256-worker fleet therefore costs one front
//! thread plus the PS apply path, not 256 parked OS threads. Because
//! the verbs, their per-worker ordering, and the codec's raw-bit `f32`
//! framing are identical to the in-thread plane, a remote day is
//! bit-for-bit identical to an in-thread day on the same schedule
//! (pinned by `tests/process_workers.rs`).
//!
//! A `Pull` the control plane gates (`PullReply::Wait`) never crosses
//! the wire: the loop parks that worker's reply and retries the pull on
//! later sweeps, so the worker blocks on its socket exactly as it used
//! to block on the front's condvar. A `Push` that completes the global
//! batch runs the flush inline on the loop thread — exactly as the
//! in-thread worker whose push completed the batch would have run it.
//!
//! Failure model (the worker-plane face of Appendix B): a worker
//! process that dies mid-day surfaces as a receive/send error on its
//! connection. If the worker held an unpushed claim, the loop reclaims
//! it with `worker_reset` — the token returns to the control plane's
//! books, the day completes on the surviving workers, and the lost
//! claim is accounted as one `failure` in the day's stats (so
//! `applied + dropped + failures == batches` still balances). The dead
//! worker's slot reopens: a replacement process may `Hello` with the
//! same id before the next day — and a worker that redials while its
//! *previous* connection is still parked in the slot replaces it, as
//! long as the old peer is verifiably dead (a live duplicate id still
//! fails the run loudly).

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::codec::{PullReply, WireMsg, WorkerReply, WorkerRequest};
use super::nbio::BufConn;
use crate::config::{ExperimentConfig, ModeKind};
use crate::coordinator::WorkerId;
use crate::obs;
use crate::shard::ShardedPs;
use crate::util::json::Json;
use crate::worker::WorkerStats;

/// How long `ensure_connected` waits for the full worker complement
/// before declaring the plane under-provisioned.
pub const WORKER_ACCEPT_DEADLINE: Duration = Duration::from_secs(120);

/// Per-connection bound on the `Hello` read: caps how long one slow or
/// silent peer can stall admission.
const HELLO_TIMEOUT: Duration = Duration::from_secs(2);

/// How long `shutdown` waits for each worker's pending `BeginDay`
/// before giving up on the farewell. Generous because the normal case
/// costs nothing — the frame is already buffered when training ends —
/// and only a dead or descheduled worker pays the wait; too short a
/// window would make a *successful* session look like a crash to a
/// worker that was briefly descheduled.
const FAREWELL_TIMEOUT: Duration = Duration::from_secs(5);

/// How long the accept path sleeps when no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Idle sweeps before the day loop parks. A burst of traffic is served
/// spin-free; a genuinely idle fleet (every worker mid-compute) costs
/// a few short sleeps per quiet spell instead of a spinning core.
const IDLE_SPINS_BEFORE_PARK: u32 = 64;

/// First park when no connection had traffic. Consecutive idle parks
/// double from here ([`idle_backoff`]) up to [`IDLE_PARK_MAX`]: a
/// briefly quiet fleet pays one 500 µs nap, a long-idle fleet (workers
/// deep in compute, or a day waiting on stragglers) converges to ~32
/// wakeups/s instead of 2000/s of pure poll overhead. Any traffic
/// resets the ladder, so burst latency stays bounded by the *first*
/// rung, not the last.
const IDLE_PARK_BASE: Duration = Duration::from_micros(500);

/// Ceiling of the idle backoff ladder. High enough to make an idle
/// front cheap, low enough that the first frame after a long lull still
/// waits at most ~16 ms before the sweep sees it.
const IDLE_PARK_MAX: Duration = Duration::from_millis(16);

/// Bounded exponential idle backoff: park `n` (0-based count of
/// consecutive idle parks) maps to `IDLE_PARK_BASE << n`, saturating at
/// [`IDLE_PARK_MAX`].
fn idle_backoff(n: u32) -> Duration {
    let base = IDLE_PARK_BASE.as_micros() as u64;
    let max = IDLE_PARK_MAX.as_micros() as u64;
    Duration::from_micros(base.saturating_mul(1u64 << n.min(16)).min(max))
}

/// The config-derived shape every connecting worker must declare in its
/// `Hello` — identity (worker id in range, no duplicates) plus the keys
/// whose silent disagreement would *not* fail fast elsewhere: the batch
/// the worker cuts (`local_batch`), the tensor shapes it trains
/// (`fields`, `emb_dim`), and the data stream it generates (`seed`,
/// `samples_per_day`). Remaining config keys are the operator's
/// contract — see docs/DEPLOY.md.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerShape {
    pub workers: usize,
    pub local_batch: u64,
    pub fields: u32,
    pub emb_dim: u32,
    pub seed: u64,
    pub samples_per_day: u64,
}

impl WorkerShape {
    /// The *one* definition of the handshake contract: the front's
    /// expectation and the worker's declaration (via
    /// [`hello`](Self::hello)) are both derived here, from the same
    /// config file + mode, so extending the contract is a single edit.
    pub fn of(cfg: &ExperimentConfig, kind: ModeKind) -> WorkerShape {
        let mode = cfg.mode(kind);
        WorkerShape {
            workers: mode.workers,
            local_batch: mode.local_batch as u64,
            fields: cfg.model.fields as u32,
            emb_dim: cfg.model.emb_dim as u32,
            seed: cfg.seed,
            samples_per_day: cfg.data.samples_per_day as u64,
        }
    }

    /// The `Hello` a worker with this shape sends at connect.
    pub fn hello(&self, worker: WorkerId) -> WorkerRequest {
        WorkerRequest::Hello {
            worker: worker as u64,
            local_batch: self.local_batch,
            fields: self.fields,
            emb_dim: self.emb_dim,
            seed: self.seed,
            samples_per_day: self.samples_per_day,
        }
    }
}

/// One connection slot per worker id (`None` = not yet connected, or
/// lost and awaiting a replacement).
type WorkerSlots = Vec<Option<BufConn>>;

/// Outcome of one accepted connection's handshake: a worker admitted to
/// a slot, or a peer that never presented a well-formed `Hello` (a port
/// scanner, a health probe, a crashed process) — dropped and logged,
/// never fatal. Only a *valid* `Hello` that disagrees with the front's
/// config is an error, because that peer is a real worker about to
/// train a diverging model.
enum Admitted {
    Worker(usize),
    Junk(String),
}

/// The front's listening socket plus one connection slot per worker id.
pub struct WorkerFront {
    listener: TcpListener,
    addr: SocketAddr,
    /// The *current epoch's* shape — a mode switch replaces it
    /// ([`begin_epoch`](Self::begin_epoch)), so replacement workers are
    /// always validated against the mode actually running.
    shape: Mutex<WorkerShape>,
    slots: Mutex<WorkerSlots>,
    /// Whether a day has been served: the first day demands the full
    /// worker complement; later days continue on survivors. An epoch
    /// switch that *grows* the complement re-arms this — the new mode's
    /// worker count is part of its shape.
    served_once: AtomicBool,
}

impl WorkerFront {
    /// Bind the worker service. Workers dial this address and are
    /// admitted lazily by [`ensure_connected`](Self::ensure_connected).
    pub fn bind(listen: &str, shape: WorkerShape) -> Result<WorkerFront> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding worker front listener on {listen}"))?;
        // Non-blocking accept lets `ensure_connected` enforce a deadline
        // instead of parking forever on a missing worker.
        listener.set_nonblocking(true).context("worker listener nonblocking")?;
        let addr = listener.local_addr().context("worker listener addr")?;
        let slots = (0..shape.workers).map(|_| None).collect();
        Ok(WorkerFront {
            listener,
            addr,
            shape: Mutex::new(shape),
            slots: Mutex::new(slots),
            served_once: AtomicBool::new(false),
        })
    }

    /// The bound address (`host:0` in the config resolves here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of worker slots currently holding a live connection.
    pub fn connected(&self) -> usize {
        self.slots.lock().unwrap().iter().filter(|s| s.is_some()).count()
    }

    /// Which worker ids currently have no connection.
    fn missing(&self) -> Vec<usize> {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter_map(|(w, s)| s.is_none().then_some(w))
            .collect()
    }

    /// Admit workers for a day. The session's *first* day demands the
    /// full complement (blocking up to `deadline` — the experiment's
    /// worker count is part of its shape); later days drain any queued
    /// replacement `Hello`s without blocking and continue on the
    /// survivors. Errors when no live worker remains at all.
    pub fn admit_for_day(&self, deadline: Duration) -> Result<()> {
        if !self.served_once.load(Ordering::Relaxed) {
            self.ensure_connected(deadline)?;
            self.served_once.store(true, Ordering::Relaxed);
            return Ok(());
        }
        self.accept_pending()?;
        let workers = self.shape.lock().unwrap().workers;
        let live = self.connected();
        anyhow::ensure!(
            live > 0,
            "no live workers remain of {workers} (all died and no replacement said Hello on {})",
            self.addr
        );
        if live < workers {
            eprintln!(
                "worker front: continuing on {live} of {workers} workers (replacements may \
                 Hello before any later day)"
            );
        }
        Ok(())
    }

    /// Accept and handshake workers until every slot is filled (new
    /// sessions and replacements for workers that died). A `Hello`
    /// whose identity or shape disagrees with the front's config fails
    /// the call — a mis-launched worker must stop the run, not train a
    /// diverging model.
    ///
    /// The `slots` lock is held only for the instants a connection is
    /// checked in or out — never across the accept/handshake wait — so
    /// [`connected`](Self::connected) and obs scrapes stay responsive
    /// for the whole (up to 120 s) admission window.
    pub fn ensure_connected(&self, deadline: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            let missing = self.missing();
            if missing.is_empty() {
                return Ok(());
            }
            // Checked every iteration — not only when the queue is
            // empty — so a stream of slow junk peers (each costing up
            // to one HELLO_TIMEOUT) cannot push the wait arbitrarily
            // past the deadline; worst-case overshoot is one handshake.
            if t0.elapsed() > deadline {
                bail!(
                    "waited {deadline:?} for {} worker(s) {missing:?} of {} to say \
                     Hello on {}",
                    missing.len(),
                    self.shape.lock().unwrap().workers,
                    self.addr
                );
            }
            match self.listener.accept() {
                Ok((stream, peer)) => self.admit(stream, peer)?,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                // A connection that aborted between arrival and accept
                // is the peer's problem; only listener-level failures
                // are fatal.
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a worker connection"),
            }
        }
    }

    /// Drain queued connections without blocking (replacement workers
    /// dialing in between days).
    fn accept_pending(&self) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => self.admit(stream, peer)?,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a worker connection"),
            }
        }
    }

    /// Handshake one accepted connection into its slot. Junk peers are
    /// logged and dropped; only a well-formed `Hello` with the wrong
    /// identity/shape errors. The `slots` lock is taken only for the
    /// final occupancy check + install, not across the handshake I/O.
    fn admit(&self, stream: TcpStream, peer: SocketAddr) -> Result<()> {
        // A handshake that cannot even configure its socket is junk,
        // not fatal: keep accepting.
        let mut conn = match BufConn::new(stream) {
            Ok(c) => c,
            Err(_) => {
                eprintln!("worker front: dropping {peer}: socket setup failed");
                return Ok(());
            }
        };
        let w = match self
            .handshake(&mut conn)
            .with_context(|| format!("worker hello from {peer}"))?
        {
            Admitted::Worker(w) => w,
            Admitted::Junk(why) => {
                // A scanner, probe or vanished peer must not abort a
                // training run; drop it and go on.
                eprintln!("worker front: ignoring connection from {peer}: {why}");
                return Ok(());
            }
        };
        let mut slots = self.slots.lock().unwrap();
        if let Some(old) = slots[w].as_mut() {
            // A worker that redials after losing its `Ok` ack (or after
            // a crash the front has not yet observed) must be able to
            // replace its *own* dead connection — aborting the run as a
            // duplicate would turn a worker-side hiccup into a dead
            // fleet. Only a verifiably dead old peer is replaced; if it
            // might still be alive, two processes claim one identity
            // and that genuinely is fatal.
            if !old.peer_dead() {
                bail!("worker hello from {peer}: duplicate worker id {w} (already connected)");
            }
            eprintln!(
                "worker front: worker {w} reconnected from {peer}; replacing its dead connection"
            );
        }
        // Ack after the slot decision so a rejected duplicate never
        // sees an `Ok`. Queued-but-unflushed ack bytes drain on the
        // event loop (or the next blocking exchange).
        if let Err(e) = conn.queue_send(&WireMsg::WorkerRep(WorkerReply::Ok)) {
            eprintln!("worker front: ignoring connection from {peer}: vanished during the Hello ack: {e}");
            return Ok(());
        }
        eprintln!("worker front: worker {w} connected from {peer}");
        slots[w] = Some(conn);
        Ok(())
    }

    /// Validate one `Hello` against the front's shape. A peer that never
    /// sends a well-formed `Hello` is [`Admitted::Junk`]; a *valid*
    /// `Hello` with the wrong identity or shape is an `Err` that fails
    /// the run (that peer is a mis-launched worker, and training on
    /// would silently diverge). Slot occupancy is *not* checked here —
    /// the caller decides under the slots lock.
    fn handshake(&self, conn: &mut BufConn) -> Result<Admitted> {
        let (worker, local_batch, fields, emb_dim, seed, samples_per_day) =
            match conn.recv_deadline(Some(HELLO_TIMEOUT)) {
                Ok(WireMsg::WorkerReq(WorkerRequest::Hello {
                    worker,
                    local_batch,
                    fields,
                    emb_dim,
                    seed,
                    samples_per_day,
                })) => (worker, local_batch, fields, emb_dim, seed, samples_per_day),
                Ok(other) => return Ok(Admitted::Junk(format!("expected Hello, got {other:?}"))),
                Err(e) => return Ok(Admitted::Junk(format!("no Hello: {e}"))),
            };
        let s = self.shape.lock().unwrap().clone();
        let s = &s;
        let w = worker as usize;
        if w >= s.workers {
            bail!("worker id {w} out of range for {} workers", s.workers);
        }
        if local_batch != s.local_batch {
            bail!(
                "local_batch mismatch: worker trains {local_batch}, front expects {} \
                 (front/worker --mode or config disagree)",
                s.local_batch
            );
        }
        if (fields, emb_dim) != (s.fields, s.emb_dim) {
            bail!(
                "model shape mismatch: worker ({fields} fields, emb {emb_dim}), front \
                 ({} fields, emb {})",
                s.fields,
                s.emb_dim
            );
        }
        if seed != s.seed {
            bail!("config seed mismatch: worker {seed}, front {}", s.seed);
        }
        if samples_per_day != s.samples_per_day {
            bail!(
                "samples_per_day mismatch: worker {samples_per_day}, front {}",
                s.samples_per_day
            );
        }
        Ok(Admitted::Worker(w))
    }

    /// Serve one training day to every connected worker on one
    /// event-loop thread: announce the day, execute each worker's PS
    /// verbs against `ps`, collect `EndOfDay` stats. Returns per-worker
    /// stats (a worker that died mid-day contributes zero batches and
    /// one `failure` per reclaimed claim; its slot reopens for a
    /// replacement).
    pub fn run_day(&self, day: usize, ps: &ShardedPs) -> Result<Vec<WorkerStats>> {
        let conns: WorkerSlots = {
            let mut slots = self.slots.lock().unwrap();
            slots.iter_mut().map(|s| s.take()).collect()
        };
        anyhow::ensure!(
            conns.iter().any(|c| c.is_some()),
            "no live worker connections for day {day}"
        );
        let had_conn: Vec<bool> = conns.iter().map(|c| c.is_some()).collect();
        let results = serve_day_loop(day, conns, ps);
        let mut slots = self.slots.lock().unwrap();
        let mut stats_out = Vec::with_capacity(results.len());
        for (w, (conn, stats)) in results.into_iter().enumerate() {
            if conn.is_none() && had_conn[w] {
                eprintln!(
                    "worker front: worker {w} lost during day {day}; slot reopened \
                     ({} claim(s) reclaimed)",
                    stats.failures
                );
            }
            slots[w] = conn;
            stats_out.push(stats);
        }
        Ok(stats_out)
    }

    /// Advance the worker plane to mode epoch `epoch` — the wire-level
    /// re-handshake of the in-place switch, run between days (the epoch
    /// boundary holds no in-flight tokens; `train_day` drains its day
    /// first). For every live worker the front answers the pending
    /// `BeginDay` with `Switch { epoch, mode }`; the worker re-derives
    /// its [`WorkerShape`] from its own config file at the announced
    /// mode and declares it back (`SwitchMode`), the front validates
    /// the declaration against `shape` and confirms with `Epoch`. After
    /// that the worker loops back to `BeginDay` and the next day is
    /// served in the new mode.
    ///
    /// Complement changes are part of the switch: workers whose id
    /// falls outside the new mode's range are retired with the
    /// `SessionOver` farewell (they exit 0 — being switched away is a
    /// clean end, not a crash); a *grown* complement re-arms the
    /// full-complement requirement, so the next day blocks until the
    /// extra `gba-train worker` processes Hello against the new shape.
    ///
    /// A worker that dies (or disagrees) mid-re-handshake fails the
    /// switch loudly: a half-switched fleet training mixed shapes would
    /// silently corrupt the new epoch, and since no tokens are in
    /// flight at the boundary, conservation is intact when the error
    /// surfaces.
    pub fn begin_epoch(&self, epoch: u64, kind: ModeKind, shape: WorkerShape) -> Result<()> {
        let mut slots = self.slots.lock().unwrap();
        let old_workers = slots.len();
        let new_workers = shape.workers;
        // Re-handshake every surviving in-range worker *first*: a
        // failure here must leave the front's own state (shape, slot
        // count, retired workers) untouched, so the session's "failed
        // switch changes nothing" contract extends to the front. Only
        // connections are lost on failure: the dead worker's, and those
        // of workers that had already confirmed the doomed epoch (a
        // mixed-epoch fleet must never serve a day).
        let keep = new_workers.min(old_workers);
        for w in 0..keep {
            let Some(conn) = slots[w].as_mut() else { continue };
            if let Err(e) = rehandshake(conn, w, epoch, kind, &shape) {
                // The failed connection is unusable mid-protocol — and
                // every *earlier* worker already confirmed the new
                // epoch, so carrying those connections into a front
                // still shaped for the old mode would train a
                // mixed-shape fleet if the caller survives the Err.
                // Sever them all (they see an abrupt close and exit
                // nonzero, the crash contract); their slots reopen for
                // replacements. Workers not yet re-handshaken are still
                // parked in the old epoch and stay.
                for confirmed in slots.iter_mut().take(w + 1) {
                    *confirmed = None;
                }
                return Err(e.context(format!(
                    "worker {w} failed the epoch-{epoch} mode re-handshake \
                     (workers 0..{w} had confirmed the new epoch and were disconnected)"
                )));
            }
        }
        // Every survivor confirmed the epoch: commit the plane to the
        // new shape. Retire out-of-range workers (a shrinking switch) —
        // being switched away is a clean end, not a crash, so failures
        // here are logged, never fatal.
        for (w, slot) in slots.iter_mut().enumerate().skip(new_workers) {
            if let Some(mut conn) = slot.take() {
                match conn.recv_deadline(None) {
                    Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay)) => {
                        let _ = conn.send_all(&WireMsg::WorkerRep(WorkerReply::SessionOver), None);
                        eprintln!(
                            "worker front: worker {w} retired by the epoch-{epoch} switch \
                             (mode {} runs {} workers)",
                            kind.as_str(),
                            new_workers
                        );
                    }
                    other => eprintln!(
                        "worker front: worker {w} dropped at retirement \
                         (no pending BeginDay: {other:?})"
                    ),
                }
            }
        }
        slots.resize_with(new_workers, || None);
        *self.shape.lock().unwrap() = shape.clone();
        if new_workers > old_workers {
            self.served_once.store(false, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Session finished *successfully*: answer each worker's pending
    /// `BeginDay` with the `SessionOver` farewell (so it exits 0) and
    /// drop the connection. Deliberately NOT done in `Drop` — a front
    /// that unwinds on an error must leave workers seeing an abrupt
    /// close, which they report as a nonzero exit so an on-failure
    /// restart policy restarts both sides; only a deliberate, clean end
    /// of training earns the farewell. Bounded best-effort: a worker
    /// that has not asked for a day within the timeout just sees the
    /// closed socket.
    pub fn shutdown(&self) {
        let mut slots = self.slots.lock().unwrap();
        for slot in slots.iter_mut() {
            if let Some(mut conn) = slot.take() {
                if matches!(
                    conn.recv_deadline(Some(FAREWELL_TIMEOUT)),
                    Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay))
                ) {
                    let _ = conn.send_all(
                        &WireMsg::WorkerRep(WorkerReply::SessionOver),
                        Some(FAREWELL_TIMEOUT),
                    );
                }
            }
        }
    }
}

/// One worker's half of the mode re-handshake, front side: consume the
/// pending `BeginDay`, announce the switch, validate the worker's
/// re-derived shape, confirm the epoch. Any wire failure or
/// disagreement is an error — the caller fails the switch.
fn rehandshake(
    conn: &mut BufConn,
    w: WorkerId,
    epoch: u64,
    kind: ModeKind,
    shape: &WorkerShape,
) -> Result<()> {
    match conn.recv_deadline(None) {
        Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay)) => {}
        Ok(other) => bail!("expected BeginDay before the switch, got {other:?}"),
        Err(e) => bail!("connection lost awaiting BeginDay: {e}"),
    }
    conn.send_all(&WireMsg::WorkerRep(WorkerReply::Switch { epoch, mode: kind }), None)
        .map_err(|e| anyhow::anyhow!("announcing the switch: {e}"))?;
    let (e, worker, workers, local_batch, fields, emb_dim, seed, samples_per_day) =
        match conn.recv_deadline(None) {
            Ok(WireMsg::WorkerReq(WorkerRequest::SwitchMode {
                epoch,
                worker,
                workers,
                local_batch,
                fields,
                emb_dim,
                seed,
                samples_per_day,
            })) => (epoch, worker, workers, local_batch, fields, emb_dim, seed, samples_per_day),
            Ok(other) => bail!("expected the SwitchMode declaration, got {other:?}"),
            Err(e) => bail!("connection lost mid re-handshake: {e}"),
        };
    anyhow::ensure!(e == epoch, "worker re-handshook epoch {e}, front is switching to {epoch}");
    anyhow::ensure!(worker as usize == w, "worker {w} declared id {worker}");
    let declared = WorkerShape {
        workers: workers as usize,
        local_batch,
        fields,
        emb_dim,
        seed,
        samples_per_day,
    };
    anyhow::ensure!(
        &declared == shape,
        "worker {w} re-derived {declared:?} for mode {}, front expects {shape:?} \
         (front/worker config files disagree)",
        kind.as_str()
    );
    conn.send_all(&WireMsg::WorkerRep(WorkerReply::Epoch { epoch }), None)
        .map_err(|e| anyhow::anyhow!("confirming epoch {epoch}: {e}"))?;
    Ok(())
}

/// Where one worker's day currently stands in the event loop.
enum Phase {
    /// Waiting for the worker's `BeginDay`.
    Opening,
    /// Day announced; serving PS verbs until `EndOfDay`.
    Serving,
    /// `EndOfDay` collected (or the connection was lost).
    Done,
}

/// Per-worker event-loop state.
struct Served {
    conn: BufConn,
    phase: Phase,
    /// Whether the worker holds a pulled-but-unpushed claim; on death it
    /// must go back to the control plane or the day never quiesces.
    claim: bool,
    /// A `Pull` the control plane gated (`Wait`): the reply is parked
    /// and the pull retried each sweep, so `Wait` never crosses the
    /// wire — the worker blocks on its socket exactly as it used to
    /// block on the front's condvar.
    pending_pull: bool,
    /// Connection still good (false once lost).
    alive: bool,
    stats: WorkerStats,
}

impl Served {
    /// The worker is gone (or spoke nonsense): reclaim any in-flight
    /// claim — the token returns to the control plane's books, counted
    /// as one failure — and mark the connection dead.
    fn lost(&mut self, w: WorkerId, day: usize, ps: &ShardedPs, why: String) {
        eprintln!("worker front: worker {w} day {day}: {why}");
        if self.claim {
            ps.worker_reset(w);
            self.stats.failures += 1;
            self.claim = false;
        }
        self.alive = false;
        self.phase = Phase::Done;
        self.pending_pull = false;
    }
}

/// The day's readiness loop: one thread sweeps every connection —
/// flush queued replies, retry gated pulls, execute newly arrived
/// requests — until every worker has delivered `EndOfDay` or died.
/// Returns, per worker id, the surviving connection (None = never
/// connected or lost) and the day's stats.
fn serve_day_loop(
    day: usize,
    conns: WorkerSlots,
    ps: &ShardedPs,
) -> Vec<(Option<BufConn>, WorkerStats)> {
    let reg = obs::global();
    let depth_gauge = reg.gauge("gba_front_ready_queue_depth");
    let polls = reg.counter("gba_front_loop_polls_total");
    let wakeups = reg.counter("gba_front_loop_wakeups_total");

    let mut served: Vec<Option<Served>> = conns
        .into_iter()
        .map(|c| {
            c.map(|conn| Served {
                conn,
                phase: Phase::Opening,
                claim: false,
                pending_pull: false,
                alive: true,
                stats: WorkerStats::default(),
            })
        })
        .collect();

    let mut idle_spins = 0u32;
    loop {
        polls.inc();
        let mut ready = 0usize;
        let mut open = 0usize;
        for w in 0..served.len() {
            let Some(st) = served[w].as_mut() else { continue };
            if matches!(st.phase, Phase::Done) {
                continue;
            }
            open += 1;
            // Push queued reply bytes toward the worker first: a reply
            // that never drains is a wedged worker, and its socket
            // error surfaces here.
            if let Err(e) = st.conn.try_flush() {
                st.lost(w, day, ps, format!("reply failed: {e}"));
                continue;
            }
            // Retry a gated pull before reading more requests — the
            // worker is parked on this reply and sends nothing new.
            if st.pending_pull {
                match ps.pull(w) {
                    PullReply::Wait => {}
                    r => {
                        st.pending_pull = false;
                        st.claim = st.claim || matches!(r, PullReply::Work(_));
                        if let Err(e) = st.conn.queue_send(&WireMsg::WorkerRep(WorkerReply::Pull(r)))
                        {
                            st.lost(w, day, ps, format!("reply failed: {e}"));
                            continue;
                        }
                        ready += 1;
                    }
                }
                continue;
            }
            // Execute newly arrived frames. One frame per sweep per
            // worker keeps the sweep fair; the protocol alternates
            // request/reply anyway, so at most one request is pending.
            match st.conn.try_recv() {
                Ok(None) => {}
                Ok(Some(msg)) => {
                    ready += 1;
                    handle_frame(st, w, day, msg, ps);
                }
                Err(e) => {
                    let why = match st.phase {
                        Phase::Opening => format!("connection lost before BeginDay: {e}"),
                        _ => format!("connection lost mid-day: {e}"),
                    };
                    st.lost(w, day, ps, why);
                }
            }
        }
        depth_gauge.set(ready as f64);
        if open == 0 {
            break;
        }
        if ready == 0 {
            idle_spins += 1;
            if idle_spins > IDLE_SPINS_BEFORE_PARK {
                wakeups.inc();
                std::thread::sleep(idle_backoff(idle_spins - IDLE_SPINS_BEFORE_PARK - 1));
            }
        } else {
            idle_spins = 0;
        }
    }

    served
        .into_iter()
        .map(|s| match s {
            None => (None, WorkerStats::default()),
            Some(st) => {
                let Served { conn, alive, stats, .. } = st;
                (alive.then_some(conn), stats)
            }
        })
        .collect()
}

/// Execute one decoded frame for worker `w`. The frame's decode already
/// installed its trace id on the loop thread, so spans emitted here —
/// and the shard apply spans an inline flush may emit below them —
/// correlate with the worker's own `worker_push` span.
fn handle_frame(st: &mut Served, w: WorkerId, day: usize, msg: WireMsg, ps: &ShardedPs) {
    let req = match msg {
        WireMsg::WorkerReq(req) => req,
        other => {
            st.lost(w, day, ps, format!("expected a worker request, got {other:?}"));
            return;
        }
    };
    if matches!(st.phase, Phase::Opening) {
        // The day opens on the worker's pending BeginDay request.
        match req {
            WorkerRequest::BeginDay => {
                if let Err(e) =
                    st.conn.queue_send(&WireMsg::WorkerRep(WorkerReply::Day { day: day as u64 }))
                {
                    st.lost(w, day, ps, format!("announcing day: {e}"));
                    return;
                }
                st.phase = Phase::Serving;
            }
            other => st.lost(w, day, ps, format!("expected BeginDay, got {other:?}")),
        }
        return;
    }
    obs::global()
        .counter(&obs::labeled("gba_front_requests_total", "rpc", req.kind_name()))
        .inc();
    let reply = match req {
        WorkerRequest::Pull { worker } if worker as usize == w => {
            // Non-blocking pull: a gate parks the reply (retried each
            // sweep) instead of parking a thread.
            match ps.pull(w) {
                PullReply::Wait => {
                    st.pending_pull = true;
                    return;
                }
                r => {
                    // The token is issued *before* the send: a send
                    // failure with work in flight must reclaim it.
                    st.claim = st.claim || matches!(r, PullReply::Work(_));
                    WorkerReply::Pull(r)
                }
            }
        }
        WorkerRequest::Push(grad) if grad.worker == w => {
            // The claim is consumed whatever the policy decides
            // (apply, buffer or drop). If this push completes the
            // global batch, the loop thread runs the flush inline —
            // exactly as the in-thread worker would have. A push
            // claiming another worker's id falls through to the
            // protocol-violation arm below — it would corrupt that
            // worker's claim accounting.
            st.claim = false;
            obs::trace::span("front_push", Json::obj().set("worker", w).set("token", grad.token));
            ps.push(grad);
            WorkerReply::Ok
        }
        WorkerRequest::Gather { keys, batch, fields } => {
            WorkerReply::Emb(ps.gather(&keys, batch as usize, fields as usize))
        }
        WorkerRequest::DenseParams => WorkerReply::Dense(ps.dense_params()),
        WorkerRequest::Reset { worker } if worker as usize == w => {
            ps.worker_reset(w);
            st.claim = false;
            WorkerReply::Ok
        }
        WorkerRequest::EndOfDay { batches, samples, failures, busy_sec } => {
            st.stats.batches = batches;
            st.stats.samples = samples;
            st.stats.failures += failures;
            st.stats.busy_sec = busy_sec;
            // Ack so the worker can move on to its next BeginDay; the
            // queued bytes drain on the farewell/next-day path, and a
            // failed queue only matters for the *next* day's accept.
            st.phase = Phase::Done;
            if st.conn.queue_send(&WireMsg::WorkerRep(WorkerReply::Ok)).is_err() {
                st.alive = false;
            }
            return;
        }
        other => {
            st.lost(w, day, ps, format!("protocol violation: {other:?}"));
            return;
        }
    };
    if let Err(e) = st.conn.queue_send(&WireMsg::WorkerRep(reply)) {
        st.lost(w, day, ps, format!("reply failed: {e}"));
    }
    // A successfully delivered Work token is the worker's problem
    // now — but only until its next push/reset, tracked above.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::CodecError;
    use crate::transport::endpoint::{Conn, SocketConn};
    use std::net::TcpStream;

    fn shape() -> WorkerShape {
        WorkerShape {
            workers: 1,
            local_batch: 16,
            fields: 4,
            emb_dim: 4,
            seed: 7,
            samples_per_day: 512,
        }
    }

    #[test]
    fn hello_handshake_admits_matching_worker() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Ok) => {}
                other => panic!("{other:?}"),
            }
            conn // keep alive until the front has admitted us
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        assert_eq!(front.connected(), 1);
        let _conn = t.join().unwrap();
    }

    /// A scanner or probe that connects and hangs up (or speaks a
    /// non-Hello frame) must be ignored, not abort the training run.
    #[test]
    fn junk_connections_are_ignored_not_fatal() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        drop(TcpStream::connect(addr).unwrap()); // connect-and-vanish
        let mut probe = SocketConn::new(TcpStream::connect(addr).unwrap());
        probe.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap(); // not a Hello
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Ok) => {}
                other => panic!("{other:?}"),
            }
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        assert_eq!(front.connected(), 1);
        let _conn = t.join().unwrap();
    }

    #[test]
    fn hello_shape_mismatch_fails_the_front_loudly() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            let mut s = shape();
            s.local_batch = 999; // launched with the wrong mode/config
            conn.send(WireMsg::WorkerReq(s.hello(0))).unwrap();
            // The front drops us without an ack.
            matches!(conn.recv(), Err(CodecError::Closed | CodecError::Io(_)))
        });
        let err = front.ensure_connected(Duration::from_secs(10)).unwrap_err();
        assert!(format!("{err:#}").contains("local_batch"), "unhelpful error: {err:#}");
        assert!(t.join().unwrap(), "mismatched worker saw an ack");
    }

    #[test]
    fn missing_worker_times_out_with_a_named_slot() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let err = front.ensure_connected(Duration::from_millis(100)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("[0]"), "which worker is missing? {msg}");
    }

    /// `connected()` (and with it obs scrapes) must answer while
    /// `ensure_connected` is mid-wait — the admission path may not hold
    /// the slots lock across its accept window.
    #[test]
    fn connected_answers_while_admission_waits() {
        let front = std::sync::Arc::new(WorkerFront::bind("127.0.0.1:0", shape()).unwrap());
        let f = front.clone();
        let t = std::thread::spawn(move || {
            // No worker ever dials: this spends its full deadline waiting.
            f.ensure_connected(Duration::from_millis(600)).unwrap_err()
        });
        std::thread::sleep(Duration::from_millis(100));
        let t0 = Instant::now();
        assert_eq!(front.connected(), 0);
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "connected() blocked behind the admission wait: {:?}",
            t0.elapsed()
        );
        t.join().unwrap();
    }

    /// A worker that redials while its previous connection is dead in
    /// the slot (a lost `Ok` ack, a crash the front has not observed)
    /// replaces that connection instead of aborting the run as a
    /// duplicate id.
    #[test]
    fn replacement_hello_swaps_out_a_dead_connection() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let first = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        front.admit_for_day(Duration::from_secs(10)).unwrap(); // arms the between-days path
        drop(first.join().unwrap()); // worker 0's connection dies

        let second = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Ok) => {}
                other => panic!("replacement not admitted: {other:?}"),
            }
            conn
        });
        // Poll: the redial and the front's close observation race.
        let t0 = Instant::now();
        loop {
            front.admit_for_day(Duration::from_secs(10)).unwrap();
            if second.is_finished() {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "replacement never admitted");
            std::thread::sleep(Duration::from_millis(10));
        }
        let _conn = second.join().unwrap();
        assert_eq!(front.connected(), 1);
    }

    /// Two *live* processes claiming one worker id is still fatal — the
    /// liveness probe only forgives verifiably dead predecessors.
    #[test]
    fn duplicate_hello_with_live_predecessor_still_fails() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let first = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        front.admit_for_day(Duration::from_secs(10)).unwrap();

        let dup = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            conn
        });
        let t0 = Instant::now();
        let err = loop {
            match front.admit_for_day(Duration::from_secs(10)) {
                Err(e) => break e,
                Ok(()) => {
                    assert!(t0.elapsed() < Duration::from_secs(10), "duplicate never rejected");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        };
        assert!(
            format!("{err:#}").contains("duplicate worker id"),
            "unhelpful duplicate error: {err:#}"
        );
        let _live = first.join().unwrap();
        let _dup = dup.join().unwrap();
    }

    /// The epoch re-handshake end to end against a scripted worker: the
    /// pending `BeginDay` is answered with `Switch`, the re-derived
    /// shape is validated, the epoch confirmed, and the connection
    /// survives into the new mode.
    #[test]
    fn epoch_rehandshake_switches_a_live_worker() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let new_shape = WorkerShape { local_batch: 8, ..shape() };
        let declared = new_shape.clone();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap();
            let epoch = match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Switch { epoch, mode }) => {
                    assert_eq!(mode, ModeKind::Gba);
                    epoch
                }
                other => panic!("expected Switch, got {other:?}"),
            };
            conn.send(WireMsg::WorkerReq(WorkerRequest::SwitchMode {
                epoch,
                worker: 0,
                workers: declared.workers as u64,
                local_batch: declared.local_batch,
                fields: declared.fields,
                emb_dim: declared.emb_dim,
                seed: declared.seed,
                samples_per_day: declared.samples_per_day,
            }))
            .unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Epoch { epoch: e }) => assert_eq!(e, epoch),
                other => panic!("expected Epoch, got {other:?}"),
            }
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        front.begin_epoch(1, ModeKind::Gba, new_shape).unwrap();
        assert_eq!(front.connected(), 1, "worker survived the switch");
        let _conn = t.join().unwrap();
    }

    /// A worker whose re-derived shape disagrees (wrong config file on
    /// its host) fails the switch loudly instead of training the old
    /// shape into the new epoch.
    #[test]
    fn epoch_rehandshake_shape_disagreement_fails_loudly() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap();
            let epoch = match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Switch { epoch, .. }) => epoch,
                other => panic!("expected Switch, got {other:?}"),
            };
            let s = shape(); // stale shape: not the new epoch's
            conn.send(WireMsg::WorkerReq(WorkerRequest::SwitchMode {
                epoch,
                worker: 0,
                workers: s.workers as u64,
                local_batch: 999,
                fields: s.fields,
                emb_dim: s.emb_dim,
                seed: s.seed,
                samples_per_day: s.samples_per_day,
            }))
            .unwrap();
            // The front bails without confirming; we see the close.
            matches!(conn.recv(), Err(_))
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        let err = front
            .begin_epoch(1, ModeKind::Gba, WorkerShape { local_batch: 8, ..shape() })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("re-derived"), "unhelpful disagreement error: {msg}");
        assert_eq!(front.connected(), 0, "the slot reopened for a replacement");
        assert!(t.join().unwrap());
    }
}

#[cfg(test)]
mod idle_backoff_tests {
    use super::*;

    #[test]
    fn backoff_ladder_doubles_from_base_and_saturates() {
        assert_eq!(idle_backoff(0), IDLE_PARK_BASE);
        assert_eq!(idle_backoff(1), IDLE_PARK_BASE * 2);
        assert_eq!(idle_backoff(2), IDLE_PARK_BASE * 4);
        assert_eq!(idle_backoff(3), IDLE_PARK_BASE * 8);
        assert_eq!(idle_backoff(5), IDLE_PARK_MAX);
        // Past the ceiling it stays there, including absurd counts that
        // would overflow a naive shift.
        assert_eq!(idle_backoff(16), IDLE_PARK_MAX);
        assert_eq!(idle_backoff(u32::MAX), IDLE_PARK_MAX);
    }

    #[test]
    fn backoff_is_monotone_nondecreasing() {
        for n in 0..20u32 {
            assert!(
                idle_backoff(n) <= idle_backoff(n + 1),
                "backoff shrank at rung {n}: {:?} > {:?}",
                idle_backoff(n),
                idle_backoff(n + 1)
            );
        }
    }

    /// The loop-wakeup counter must keep counting parks under the
    /// backoff ladder — an idle day loop (one worker that begins a day
    /// and then goes quiet) parks repeatedly, and the obs registry sees
    /// every one of those naps.
    #[test]
    fn idle_day_loop_still_counts_wakeups() {
        use crate::coordinator::modes::GbaPolicy;
        use crate::embedding::EmbeddingConfig;
        use crate::optim::Sgd;
        use crate::runtime::{HostTensor, VariantDims};
        use crate::transport::endpoint::{Conn, SocketConn};
        use std::net::TcpStream;

        let shape = WorkerShape {
            workers: 1,
            local_batch: 16,
            fields: 4,
            emb_dim: 4,
            seed: 7,
            samples_per_day: 512,
        };
        let ps = ShardedPs::new(
            VariantDims { fields: 4, emb_dim: 4, hidden1: 8, hidden2: 4, mlp_in: 20 },
            vec![HostTensor { shape: vec![4], data: vec![0.0; 4] }],
            EmbeddingConfig { dim: 4, init_scale: 0.0, seed: 1, shards: 2 },
            Box::new(Sgd { lr: 1.0 }),
            Box::new(Sgd { lr: 1.0 }),
            Box::new(GbaPolicy::with_iota(1, 3)),
        );

        let before = obs::global().counter("gba_front_loop_wakeups_total").get();
        let front = WorkerFront::bind("127.0.0.1:0", shape.clone()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape.hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Day { .. })));
            // Go quiet long enough for the sweep to run the idle ladder,
            // then finish the day so run_day can return.
            std::thread::sleep(Duration::from_millis(100));
            conn.send(WireMsg::WorkerReq(WorkerRequest::EndOfDay {
                batches: 0,
                samples: 0,
                failures: 0,
                busy_sec: 0.0,
            }))
            .unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        front.run_day(0, &ps).unwrap();
        let _conn = t.join().unwrap();
        assert!(
            obs::global().counter("gba_front_loop_wakeups_total").get() > before,
            "a 100 ms idle spell parked zero times"
        );
    }
}
