//! Front-side worker service: the half of the remote worker plane that
//! lives in the trainer process.
//!
//! With `[cluster] workers = "remote"` the session's Algorithm-1 loops
//! run in separate `gba-train worker` OS processes. The front binds one
//! listening socket ([`WorkerFront::bind`]), waits for `mode.workers`
//! connect-time `Hello` identity/shape handshakes
//! ([`WorkerFront::ensure_connected`]), and then serves each worker's
//! day over the existing length-prefixed codec
//! ([`WorkerFront::run_day`]): one serving thread per worker executes
//! `Pull`/`Push`/`Gather`/`DenseParams`/`Reset` requests against the
//! shared PS front — the token-control plane is driven *unchanged*, by
//! the same five verbs the in-thread workers call — and collects the
//! `EndOfDay` stats. Because the verbs, their ordering per worker, and
//! the codec's raw-bit `f32` framing are identical to the in-thread
//! plane, a remote day is bit-for-bit identical to an in-thread day on
//! the same schedule (pinned by `tests/process_workers.rs`).
//!
//! Failure model (the worker-plane face of Appendix B): a worker
//! process that dies mid-day surfaces as a receive/send error on its
//! connection. If the worker held an unpushed claim, the serving thread
//! reclaims it with `worker_reset` — the token returns to the control
//! plane's books, the day completes on the surviving workers, and the
//! lost claim is accounted as one `failure` in the day's stats (so
//! `applied + dropped + failures == batches` still balances). The dead
//! worker's slot reopens: a replacement process may `Hello` with the
//! same id before the next day.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::codec::{PullReply, WireMsg, WorkerReply, WorkerRequest};
use super::endpoint::{Conn, SocketConn};
use crate::config::{ExperimentConfig, ModeKind};
use crate::coordinator::WorkerId;
use crate::obs;
use crate::shard::ShardedPs;
use crate::util::json::Json;
use crate::worker::WorkerStats;

/// How long `ensure_connected` waits for the full worker complement
/// before declaring the plane under-provisioned.
pub const WORKER_ACCEPT_DEADLINE: Duration = Duration::from_secs(120);

/// Per-connection bound on the `Hello` read: caps how long one slow or
/// silent peer can stall the accept loop (and the slots lock).
const HELLO_TIMEOUT: Duration = Duration::from_secs(2);

/// How long `shutdown` waits for each worker's pending `BeginDay`
/// before giving up on the farewell. Generous because the normal case
/// costs nothing — the frame is already buffered when training ends —
/// and only a dead or descheduled worker pays the wait; too short a
/// window would make a *successful* session look like a crash to a
/// worker that was briefly descheduled.
const FAREWELL_TIMEOUT: Duration = Duration::from_secs(5);

/// The config-derived shape every connecting worker must declare in its
/// `Hello` — identity (worker id in range, no duplicates) plus the keys
/// whose silent disagreement would *not* fail fast elsewhere: the batch
/// the worker cuts (`local_batch`), the tensor shapes it trains
/// (`fields`, `emb_dim`), and the data stream it generates (`seed`,
/// `samples_per_day`). Remaining config keys are the operator's
/// contract — see docs/DEPLOY.md.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerShape {
    pub workers: usize,
    pub local_batch: u64,
    pub fields: u32,
    pub emb_dim: u32,
    pub seed: u64,
    pub samples_per_day: u64,
}

impl WorkerShape {
    /// The *one* definition of the handshake contract: the front's
    /// expectation and the worker's declaration (via
    /// [`hello`](Self::hello)) are both derived here, from the same
    /// config file + mode, so extending the contract is a single edit.
    pub fn of(cfg: &ExperimentConfig, kind: ModeKind) -> WorkerShape {
        let mode = cfg.mode(kind);
        WorkerShape {
            workers: mode.workers,
            local_batch: mode.local_batch as u64,
            fields: cfg.model.fields as u32,
            emb_dim: cfg.model.emb_dim as u32,
            seed: cfg.seed,
            samples_per_day: cfg.data.samples_per_day as u64,
        }
    }

    /// The `Hello` a worker with this shape sends at connect.
    pub fn hello(&self, worker: WorkerId) -> WorkerRequest {
        WorkerRequest::Hello {
            worker: worker as u64,
            local_batch: self.local_batch,
            fields: self.fields,
            emb_dim: self.emb_dim,
            seed: self.seed,
            samples_per_day: self.samples_per_day,
        }
    }
}

/// One connection slot per worker id (`None` = not yet connected, or
/// lost and awaiting a replacement).
type WorkerSlots = Vec<Option<SocketConn>>;

/// Outcome of one accepted connection's handshake: a worker admitted to
/// a slot, or a peer that never presented a well-formed `Hello` (a port
/// scanner, a health probe, a crashed process) — dropped and logged,
/// never fatal. Only a *valid* `Hello` that disagrees with the front's
/// config is an error, because that peer is a real worker about to
/// train a diverging model.
enum Admitted {
    Worker(usize),
    Junk(String),
}

/// The front's listening socket plus one connection slot per worker id.
pub struct WorkerFront {
    listener: TcpListener,
    addr: SocketAddr,
    /// The *current epoch's* shape — a mode switch replaces it
    /// ([`begin_epoch`](Self::begin_epoch)), so replacement workers are
    /// always validated against the mode actually running.
    shape: Mutex<WorkerShape>,
    slots: Mutex<WorkerSlots>,
    /// Whether a day has been served: the first day demands the full
    /// worker complement; later days continue on survivors. An epoch
    /// switch that *grows* the complement re-arms this — the new mode's
    /// worker count is part of its shape.
    served_once: AtomicBool,
}

impl WorkerFront {
    /// Bind the worker service. Workers dial this address and are
    /// admitted lazily by [`ensure_connected`](Self::ensure_connected).
    pub fn bind(listen: &str, shape: WorkerShape) -> Result<WorkerFront> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding worker front listener on {listen}"))?;
        // Non-blocking accept lets `ensure_connected` enforce a deadline
        // instead of parking forever on a missing worker.
        listener.set_nonblocking(true).context("worker listener nonblocking")?;
        let addr = listener.local_addr().context("worker listener addr")?;
        let slots = (0..shape.workers).map(|_| None).collect();
        Ok(WorkerFront {
            listener,
            addr,
            shape: Mutex::new(shape),
            slots: Mutex::new(slots),
            served_once: AtomicBool::new(false),
        })
    }

    /// The bound address (`host:0` in the config resolves here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of worker slots currently holding a live connection.
    pub fn connected(&self) -> usize {
        self.slots.lock().unwrap().iter().filter(|s| s.is_some()).count()
    }

    /// Admit workers for a day. The session's *first* day demands the
    /// full complement (blocking up to `deadline` — the experiment's
    /// worker count is part of its shape); later days drain any queued
    /// replacement `Hello`s without blocking and continue on the
    /// survivors. Errors when no live worker remains at all.
    pub fn admit_for_day(&self, deadline: Duration) -> Result<()> {
        if !self.served_once.load(Ordering::Relaxed) {
            self.ensure_connected(deadline)?;
            self.served_once.store(true, Ordering::Relaxed);
            return Ok(());
        }
        self.accept_pending()?;
        let workers = self.shape.lock().unwrap().workers;
        let live = self.connected();
        anyhow::ensure!(
            live > 0,
            "no live workers remain of {workers} (all died and no replacement said Hello on {})",
            self.addr
        );
        if live < workers {
            eprintln!(
                "worker front: continuing on {live} of {workers} workers (replacements may \
                 Hello before any later day)"
            );
        }
        Ok(())
    }

    /// Accept and handshake workers until every slot is filled (new
    /// sessions and replacements for workers that died). A `Hello`
    /// whose identity or shape disagrees with the front's config fails
    /// the call — a mis-launched worker must stop the run, not train a
    /// diverging model.
    pub fn ensure_connected(&self, deadline: Duration) -> Result<()> {
        let mut slots = self.slots.lock().unwrap();
        let t0 = Instant::now();
        while slots.iter().any(|s| s.is_none()) {
            // Checked every iteration — not only when the queue is
            // empty — so a stream of slow junk peers (each costing up
            // to one HELLO_TIMEOUT) cannot push the wait arbitrarily
            // past the deadline; worst-case overshoot is one handshake.
            if t0.elapsed() > deadline {
                let missing: Vec<usize> = slots
                    .iter()
                    .enumerate()
                    .filter_map(|(w, s)| s.is_none().then_some(w))
                    .collect();
                bail!(
                    "waited {deadline:?} for {} worker(s) {missing:?} of {} to say \
                     Hello on {}",
                    missing.len(),
                    self.shape.lock().unwrap().workers,
                    self.addr
                );
            }
            match self.listener.accept() {
                Ok((stream, peer)) => self.admit(stream, peer, &mut slots)?,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                // A connection that aborted between arrival and accept
                // is the peer's problem; only listener-level failures
                // are fatal.
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a worker connection"),
            }
        }
        Ok(())
    }

    /// Drain queued connections without blocking (replacement workers
    /// dialing in between days).
    fn accept_pending(&self) -> Result<()> {
        let mut slots = self.slots.lock().unwrap();
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => self.admit(stream, peer, &mut slots)?,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a worker connection"),
            }
        }
    }

    /// Handshake one accepted connection into its slot. Junk peers are
    /// logged and dropped; only a well-formed `Hello` with the wrong
    /// identity/shape errors.
    fn admit(
        &self,
        stream: TcpStream,
        peer: SocketAddr,
        slots: &mut WorkerSlots,
    ) -> Result<()> {
        // A handshake that cannot even configure its socket is junk,
        // not fatal: keep accepting.
        if stream.set_nonblocking(false).is_err()
            || stream.set_read_timeout(Some(HELLO_TIMEOUT)).is_err()
        {
            eprintln!("worker front: dropping {peer}: socket setup failed");
            return Ok(());
        }
        let mut conn = SocketConn::new(stream);
        match self
            .handshake(&mut conn, slots)
            .with_context(|| format!("worker hello from {peer}"))?
        {
            Admitted::Worker(w) => {
                conn.stream.set_read_timeout(None).context("clearing hello timeout")?;
                eprintln!("worker front: worker {w} connected from {peer}");
                slots[w] = Some(conn);
            }
            Admitted::Junk(why) => {
                // A scanner, probe or vanished peer must not abort a
                // training run; drop it and go on.
                eprintln!("worker front: ignoring connection from {peer}: {why}");
            }
        }
        Ok(())
    }

    /// Validate one `Hello` against the front's shape. A peer that never
    /// sends a well-formed `Hello` is [`Admitted::Junk`]; a *valid*
    /// `Hello` with the wrong identity or shape is an `Err` that fails
    /// the run (that peer is a mis-launched worker, and training on
    /// would silently diverge).
    fn handshake(&self, conn: &mut SocketConn, slots: &[Option<SocketConn>]) -> Result<Admitted> {
        let (worker, local_batch, fields, emb_dim, seed, samples_per_day) = match conn.recv() {
            Ok(WireMsg::WorkerReq(WorkerRequest::Hello {
                worker,
                local_batch,
                fields,
                emb_dim,
                seed,
                samples_per_day,
            })) => (worker, local_batch, fields, emb_dim, seed, samples_per_day),
            Ok(other) => return Ok(Admitted::Junk(format!("expected Hello, got {other:?}"))),
            Err(e) => return Ok(Admitted::Junk(format!("no Hello: {e}"))),
        };
        let s = self.shape.lock().unwrap().clone();
        let s = &s;
        let w = worker as usize;
        if w >= s.workers {
            bail!("worker id {w} out of range for {} workers", s.workers);
        }
        if slots[w].is_some() {
            bail!("duplicate worker id {w} (already connected)");
        }
        if local_batch != s.local_batch {
            bail!(
                "local_batch mismatch: worker trains {local_batch}, front expects {} \
                 (front/worker --mode or config disagree)",
                s.local_batch
            );
        }
        if (fields, emb_dim) != (s.fields, s.emb_dim) {
            bail!(
                "model shape mismatch: worker ({fields} fields, emb {emb_dim}), front \
                 ({} fields, emb {})",
                s.fields,
                s.emb_dim
            );
        }
        if seed != s.seed {
            bail!("config seed mismatch: worker {seed}, front {}", s.seed);
        }
        if samples_per_day != s.samples_per_day {
            bail!(
                "samples_per_day mismatch: worker {samples_per_day}, front {}",
                s.samples_per_day
            );
        }
        if let Err(e) = conn.send(WireMsg::WorkerRep(WorkerReply::Ok)) {
            return Ok(Admitted::Junk(format!("vanished during the Hello ack: {e}")));
        }
        Ok(Admitted::Worker(w))
    }

    /// Serve one training day to every connected worker: announce the
    /// day, execute each worker's PS verbs against `ps`, collect
    /// `EndOfDay` stats. Returns per-worker stats (a worker that died
    /// mid-day contributes zero batches and one `failure` per reclaimed
    /// claim; its slot reopens for a replacement).
    pub fn run_day(&self, day: usize, ps: &ShardedPs) -> Result<Vec<WorkerStats>> {
        let conns: WorkerSlots = {
            let mut slots = self.slots.lock().unwrap();
            slots.iter_mut().map(|s| s.take()).collect()
        };
        anyhow::ensure!(
            conns.iter().any(|c| c.is_some()),
            "no live worker connections for day {day}"
        );
        let mut results = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = conns
                .into_iter()
                .enumerate()
                .map(|(w, conn)| {
                    scope.spawn(move || match conn {
                        Some(mut c) => {
                            let (alive, stats) = serve_worker_day(w, day, &mut c, ps);
                            (alive.then_some(c), stats)
                        }
                        None => (None, WorkerStats::default()),
                    })
                })
                .collect();
            results = handles
                .into_iter()
                .map(|h| h.join().expect("worker serving thread panicked"))
                .collect();
        });
        let mut slots = self.slots.lock().unwrap();
        let mut stats_out = Vec::with_capacity(results.len());
        for (w, (conn, stats)) in results.into_iter().enumerate() {
            if conn.is_none() {
                eprintln!(
                    "worker front: worker {w} lost during day {day}; slot reopened \
                     ({} claim(s) reclaimed)",
                    stats.failures
                );
            }
            slots[w] = conn;
            stats_out.push(stats);
        }
        Ok(stats_out)
    }

    /// Advance the worker plane to mode epoch `epoch` — the wire-level
    /// re-handshake of the in-place switch, run between days (the epoch
    /// boundary holds no in-flight tokens; `train_day` drains its day
    /// first). For every live worker the front answers the pending
    /// `BeginDay` with `Switch { epoch, mode }`; the worker re-derives
    /// its [`WorkerShape`] from its own config file at the announced
    /// mode and declares it back (`SwitchMode`), the front validates
    /// the declaration against `shape` and confirms with `Epoch`. After
    /// that the worker loops back to `BeginDay` and the next day is
    /// served in the new mode.
    ///
    /// Complement changes are part of the switch: workers whose id
    /// falls outside the new mode's range are retired with the
    /// `SessionOver` farewell (they exit 0 — being switched away is a
    /// clean end, not a crash); a *grown* complement re-arms the
    /// full-complement requirement, so the next day blocks until the
    /// extra `gba-train worker` processes Hello against the new shape.
    ///
    /// A worker that dies (or disagrees) mid-re-handshake fails the
    /// switch loudly: a half-switched fleet training mixed shapes would
    /// silently corrupt the new epoch, and since no tokens are in
    /// flight at the boundary, conservation is intact when the error
    /// surfaces.
    pub fn begin_epoch(&self, epoch: u64, kind: ModeKind, shape: WorkerShape) -> Result<()> {
        let mut slots = self.slots.lock().unwrap();
        let old_workers = slots.len();
        let new_workers = shape.workers;
        // Re-handshake every surviving in-range worker *first*: a
        // failure here must leave the front's own state (shape, slot
        // count, retired workers) untouched, so the session's "failed
        // switch changes nothing" contract extends to the front. Only
        // connections are lost on failure: the dead worker's, and those
        // of workers that had already confirmed the doomed epoch (a
        // mixed-epoch fleet must never serve a day).
        let keep = new_workers.min(old_workers);
        for w in 0..keep {
            let Some(conn) = slots[w].as_mut() else { continue };
            if let Err(e) = rehandshake(conn, w, epoch, kind, &shape) {
                // The failed connection is unusable mid-protocol — and
                // every *earlier* worker already confirmed the new
                // epoch, so carrying those connections into a front
                // still shaped for the old mode would train a
                // mixed-shape fleet if the caller survives the Err.
                // Sever them all (they see an abrupt close and exit
                // nonzero, the crash contract); their slots reopen for
                // replacements. Workers not yet re-handshaken are still
                // parked in the old epoch and stay.
                for confirmed in slots.iter_mut().take(w + 1) {
                    *confirmed = None;
                }
                return Err(e.context(format!(
                    "worker {w} failed the epoch-{epoch} mode re-handshake \
                     (workers 0..{w} had confirmed the new epoch and were disconnected)"
                )));
            }
        }
        // Every survivor confirmed the epoch: commit the plane to the
        // new shape. Retire out-of-range workers (a shrinking switch) —
        // being switched away is a clean end, not a crash, so failures
        // here are logged, never fatal.
        for (w, slot) in slots.iter_mut().enumerate().skip(new_workers) {
            if let Some(mut conn) = slot.take() {
                match conn.recv() {
                    Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay)) => {
                        let _ = conn.send(WireMsg::WorkerRep(WorkerReply::SessionOver));
                        eprintln!(
                            "worker front: worker {w} retired by the epoch-{epoch} switch \
                             (mode {} runs {} workers)",
                            kind.as_str(),
                            new_workers
                        );
                    }
                    other => eprintln!(
                        "worker front: worker {w} dropped at retirement \
                         (no pending BeginDay: {other:?})"
                    ),
                }
            }
        }
        slots.resize_with(new_workers, || None);
        *self.shape.lock().unwrap() = shape.clone();
        if new_workers > old_workers {
            self.served_once.store(false, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Session finished *successfully*: answer each worker's pending
    /// `BeginDay` with the `SessionOver` farewell (so it exits 0) and
    /// drop the connection. Deliberately NOT done in `Drop` — a front
    /// that unwinds on an error must leave workers seeing an abrupt
    /// close, which they report as a nonzero exit so an on-failure
    /// restart policy restarts both sides; only a deliberate, clean end
    /// of training earns the farewell. Bounded best-effort: a worker
    /// that has not asked for a day within the timeout just sees the
    /// closed socket.
    pub fn shutdown(&self) {
        let mut slots = self.slots.lock().unwrap();
        for slot in slots.iter_mut() {
            if let Some(mut conn) = slot.take() {
                let _ = conn.stream.set_read_timeout(Some(FAREWELL_TIMEOUT));
                if matches!(conn.recv(), Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay))) {
                    let _ = conn.send(WireMsg::WorkerRep(WorkerReply::SessionOver));
                }
            }
        }
    }
}

/// One worker's half of the mode re-handshake, front side: consume the
/// pending `BeginDay`, announce the switch, validate the worker's
/// re-derived shape, confirm the epoch. Any wire failure or
/// disagreement is an error — the caller fails the switch.
fn rehandshake(
    conn: &mut SocketConn,
    w: WorkerId,
    epoch: u64,
    kind: ModeKind,
    shape: &WorkerShape,
) -> Result<()> {
    match conn.recv() {
        Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay)) => {}
        Ok(other) => bail!("expected BeginDay before the switch, got {other:?}"),
        Err(e) => bail!("connection lost awaiting BeginDay: {e}"),
    }
    conn.send(WireMsg::WorkerRep(WorkerReply::Switch { epoch, mode: kind }))
        .map_err(|e| anyhow::anyhow!("announcing the switch: {e}"))?;
    let (e, worker, workers, local_batch, fields, emb_dim, seed, samples_per_day) =
        match conn.recv() {
            Ok(WireMsg::WorkerReq(WorkerRequest::SwitchMode {
                epoch,
                worker,
                workers,
                local_batch,
                fields,
                emb_dim,
                seed,
                samples_per_day,
            })) => (epoch, worker, workers, local_batch, fields, emb_dim, seed, samples_per_day),
            Ok(other) => bail!("expected the SwitchMode declaration, got {other:?}"),
            Err(e) => bail!("connection lost mid re-handshake: {e}"),
        };
    anyhow::ensure!(e == epoch, "worker re-handshook epoch {e}, front is switching to {epoch}");
    anyhow::ensure!(worker as usize == w, "worker {w} declared id {worker}");
    let declared = WorkerShape {
        workers: workers as usize,
        local_batch,
        fields,
        emb_dim,
        seed,
        samples_per_day,
    };
    anyhow::ensure!(
        &declared == shape,
        "worker {w} re-derived {declared:?} for mode {}, front expects {shape:?} \
         (front/worker config files disagree)",
        kind.as_str()
    );
    conn.send(WireMsg::WorkerRep(WorkerReply::Epoch { epoch }))
        .map_err(|e| anyhow::anyhow!("confirming epoch {epoch}: {e}"))?;
    Ok(())
}

/// Serve one worker's day on its connection. Returns whether the
/// connection is still good and the worker's stats (synthesized, with
/// any reclaimed claim counted as a failure, when the worker died).
fn serve_worker_day(
    w: WorkerId,
    day: usize,
    conn: &mut dyn Conn,
    ps: &ShardedPs,
) -> (bool, WorkerStats) {
    let mut stats = WorkerStats::default();
    // Whether the worker holds a pulled-but-unpushed claim; on death it
    // must go back to the control plane or the day never quiesces.
    let mut claim = false;

    // The worker is gone (or spoke nonsense): reclaim any in-flight
    // claim — the token returns to the control plane's books, counted
    // as one failure — and report the connection dead.
    let lost = |claim: bool, stats: &mut WorkerStats, why: String| {
        eprintln!("worker front: worker {w} day {day}: {why}");
        if claim {
            ps.worker_reset(w);
            stats.failures += 1;
        }
    };

    // The day opens on the worker's pending BeginDay request.
    match conn.recv() {
        Ok(WireMsg::WorkerReq(WorkerRequest::BeginDay)) => {}
        Ok(other) => {
            lost(claim, &mut stats, format!("expected BeginDay, got {other:?}"));
            return (false, stats);
        }
        Err(e) => {
            lost(claim, &mut stats, format!("connection lost before BeginDay: {e}"));
            return (false, stats);
        }
    }
    if let Err(e) = conn.send(WireMsg::WorkerRep(WorkerReply::Day { day: day as u64 })) {
        lost(claim, &mut stats, format!("announcing day: {e}"));
        return (false, stats);
    }

    loop {
        let req = match conn.recv() {
            Ok(WireMsg::WorkerReq(req)) => req,
            Ok(other) => {
                lost(claim, &mut stats, format!("expected a worker request, got {other:?}"));
                return (false, stats);
            }
            Err(e) => {
                lost(claim, &mut stats, format!("connection lost mid-day: {e}"));
                return (false, stats);
            }
        };
        obs::global()
            .counter(&obs::labeled("gba_front_requests_total", "rpc", req.kind_name()))
            .inc();
        let reply = match req {
            WorkerRequest::Pull { worker } if worker as usize == w => {
                let r = ps.pull_blocking(w);
                // The token is issued *before* the send: a send failure
                // with work in flight must reclaim it.
                claim = claim || matches!(r, PullReply::Work(_));
                WorkerReply::Pull(r)
            }
            WorkerRequest::Push(grad) if grad.worker == w => {
                // The claim is consumed whatever the policy decides
                // (apply, buffer or drop). If this push completes the
                // global batch, this serving thread runs the flush —
                // exactly as the in-thread worker would have. A push
                // claiming another worker's id falls through to the
                // protocol-violation arm below — it would corrupt that
                // worker's claim accounting.
                claim = false;
                // The decoded frame installed the worker's trace id on
                // this serving thread, so this span — and the shard
                // apply spans the flush may emit below it — correlate
                // with the worker's own `worker_push` span.
                obs::trace::span(
                    "front_push",
                    Json::obj().set("worker", w).set("token", grad.token),
                );
                ps.push(grad);
                WorkerReply::Ok
            }
            WorkerRequest::Gather { keys, batch, fields } => {
                WorkerReply::Emb(ps.gather(&keys, batch as usize, fields as usize))
            }
            WorkerRequest::DenseParams => WorkerReply::Dense(ps.dense_params()),
            WorkerRequest::Reset { worker } if worker as usize == w => {
                ps.worker_reset(w);
                claim = false;
                WorkerReply::Ok
            }
            WorkerRequest::EndOfDay { batches, samples, failures, busy_sec } => {
                stats.batches = batches;
                stats.samples = samples;
                stats.failures += failures;
                stats.busy_sec = busy_sec;
                // Ack so the worker can move on to its next BeginDay; a
                // failed ack only matters for the *next* day's accept.
                let alive = conn.send(WireMsg::WorkerRep(WorkerReply::Ok)).is_ok();
                return (alive, stats);
            }
            other => {
                lost(claim, &mut stats, format!("protocol violation: {other:?}"));
                return (false, stats);
            }
        };
        if let Err(e) = conn.send(WireMsg::WorkerRep(reply)) {
            lost(claim, &mut stats, format!("reply failed: {e}"));
            return (false, stats);
        }
        // A successfully delivered Work token is the worker's problem
        // now — but only until its next push/reset, tracked above.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::CodecError;
    use std::net::TcpStream;

    fn shape() -> WorkerShape {
        WorkerShape {
            workers: 1,
            local_batch: 16,
            fields: 4,
            emb_dim: 4,
            seed: 7,
            samples_per_day: 512,
        }
    }

    #[test]
    fn hello_handshake_admits_matching_worker() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Ok) => {}
                other => panic!("{other:?}"),
            }
            conn // keep alive until the front has admitted us
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        assert_eq!(front.connected(), 1);
        let _conn = t.join().unwrap();
    }

    /// A scanner or probe that connects and hangs up (or speaks a
    /// non-Hello frame) must be ignored, not abort the training run.
    #[test]
    fn junk_connections_are_ignored_not_fatal() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        drop(TcpStream::connect(addr).unwrap()); // connect-and-vanish
        let mut probe = SocketConn::new(TcpStream::connect(addr).unwrap());
        probe.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap(); // not a Hello
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Ok) => {}
                other => panic!("{other:?}"),
            }
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        assert_eq!(front.connected(), 1);
        let _conn = t.join().unwrap();
    }

    #[test]
    fn hello_shape_mismatch_fails_the_front_loudly() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            let mut s = shape();
            s.local_batch = 999; // launched with the wrong mode/config
            conn.send(WireMsg::WorkerReq(s.hello(0))).unwrap();
            // The front drops us without an ack.
            matches!(conn.recv(), Err(CodecError::Closed | CodecError::Io(_)))
        });
        let err = front.ensure_connected(Duration::from_secs(10)).unwrap_err();
        assert!(format!("{err:#}").contains("local_batch"), "unhelpful error: {err:#}");
        assert!(t.join().unwrap(), "mismatched worker saw an ack");
    }

    #[test]
    fn missing_worker_times_out_with_a_named_slot() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let err = front.ensure_connected(Duration::from_millis(100)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("[0]"), "which worker is missing? {msg}");
    }

    /// The epoch re-handshake end to end against a scripted worker: the
    /// pending `BeginDay` is answered with `Switch`, the re-derived
    /// shape is validated, the epoch confirmed, and the connection
    /// survives into the new mode.
    #[test]
    fn epoch_rehandshake_switches_a_live_worker() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let new_shape = WorkerShape { local_batch: 8, ..shape() };
        let declared = new_shape.clone();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap();
            let epoch = match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Switch { epoch, mode }) => {
                    assert_eq!(mode, ModeKind::Gba);
                    epoch
                }
                other => panic!("expected Switch, got {other:?}"),
            };
            conn.send(WireMsg::WorkerReq(WorkerRequest::SwitchMode {
                epoch,
                worker: 0,
                workers: declared.workers as u64,
                local_batch: declared.local_batch,
                fields: declared.fields,
                emb_dim: declared.emb_dim,
                seed: declared.seed,
                samples_per_day: declared.samples_per_day,
            }))
            .unwrap();
            match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Epoch { epoch: e }) => assert_eq!(e, epoch),
                other => panic!("expected Epoch, got {other:?}"),
            }
            conn
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        front.begin_epoch(1, ModeKind::Gba, new_shape).unwrap();
        assert_eq!(front.connected(), 1, "worker survived the switch");
        let _conn = t.join().unwrap();
    }

    /// A worker whose re-derived shape disagrees (wrong config file on
    /// its host) fails the switch loudly instead of training the old
    /// shape into the new epoch.
    #[test]
    fn epoch_rehandshake_shape_disagreement_fails_loudly() {
        let front = WorkerFront::bind("127.0.0.1:0", shape()).unwrap();
        let addr = front.addr();
        let t = std::thread::spawn(move || {
            let mut conn = SocketConn::new(TcpStream::connect(addr).unwrap());
            conn.send(WireMsg::WorkerReq(shape().hello(0))).unwrap();
            assert!(matches!(conn.recv().unwrap(), WireMsg::WorkerRep(WorkerReply::Ok)));
            conn.send(WireMsg::WorkerReq(WorkerRequest::BeginDay)).unwrap();
            let epoch = match conn.recv().unwrap() {
                WireMsg::WorkerRep(WorkerReply::Switch { epoch, .. }) => epoch,
                other => panic!("expected Switch, got {other:?}"),
            };
            let s = shape(); // stale shape: not the new epoch's
            conn.send(WireMsg::WorkerReq(WorkerRequest::SwitchMode {
                epoch,
                worker: 0,
                workers: s.workers as u64,
                local_batch: 999,
                fields: s.fields,
                emb_dim: s.emb_dim,
                seed: s.seed,
                samples_per_day: s.samples_per_day,
            }))
            .unwrap();
            // The front bails without confirming; we see the close.
            matches!(conn.recv(), Err(_))
        });
        front.ensure_connected(Duration::from_secs(10)).unwrap();
        let err = front
            .begin_epoch(1, ModeKind::Gba, WorkerShape { local_batch: 8, ..shape() })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("re-derived"), "unhelpful disagreement error: {msg}");
        assert_eq!(front.connected(), 0, "the slot reopened for a replacement");
        assert!(t.join().unwrap());
    }
}
