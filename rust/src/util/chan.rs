//! Multi-producer / multi-consumer channel built on `Mutex` + `Condvar`.
//!
//! The PS push/pull services and the worker pools need MPMC semantics
//! (std::sync::mpsc is MPSC-only and crossbeam-channel is unavailable
//! offline). Supports bounded and unbounded queues, blocking and
//! non-blocking receive, timeouts and close-on-drop semantics.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Inner<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct State<T> {
    items: VecDeque<T>,
    cap: Option<usize>,
    senders: usize,
    receivers: usize,
    closed: bool,
}

pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SendError<T> {
    /// All receivers dropped or channel explicitly closed.
    Closed(T),
}

#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Channel empty and all senders dropped (or closed).
    Closed,
    /// try/timeout receive found nothing (senders still alive).
    Empty,
}

/// Create an unbounded channel.
pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    channel(None)
}

/// One end of a bidirectional in-process connection (see [`duplex`]).
pub struct Duplex<T> {
    pub tx: Sender<T>,
    pub rx: Receiver<T>,
}

/// A connected pair of bidirectional endpoints: what `a` sends, `b`
/// receives, and vice versa. This is the in-process stand-in for a socket
/// — the transport layer's `InProc` shard endpoints are exactly one
/// `duplex` pair per shard. Dropping either end closes that direction,
/// so a dead peer surfaces as `RecvError::Closed`/`SendError::Closed`
/// just like a broken socket surfaces as an I/O error.
pub fn duplex<T>() -> (Duplex<T>, Duplex<T>) {
    let (a_tx, b_rx) = unbounded();
    let (b_tx, a_rx) = unbounded();
    (Duplex { tx: a_tx, rx: a_rx }, Duplex { tx: b_tx, rx: b_rx })
}

/// Create a bounded channel; `send` blocks when `cap` items are queued.
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    channel(Some(cap.max(1)))
}

fn channel<T>(cap: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(Inner {
        q: Mutex::new(State {
            items: VecDeque::new(),
            cap,
            senders: 1,
            receivers: 1,
            closed: false,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { inner: inner.clone() }, Receiver { inner })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.q.lock().unwrap().senders += 1;
        Sender { inner: self.inner.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.inner.q.lock().unwrap().receivers += 1;
        Receiver { inner: self.inner.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.inner.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.inner.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.inner.q.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.inner.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send (respects the bound).
    pub fn send(&self, v: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed || st.receivers == 0 {
                return Err(SendError::Closed(v));
            }
            match st.cap {
                Some(cap) if st.items.len() >= cap => {
                    st = self.inner.not_full.wait(st).unwrap();
                }
                _ => break,
            }
        }
        st.items.push_back(v);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send: fails with `Closed` if full would block? No —
    /// returns the value back if the channel is full or closed.
    pub fn try_send(&self, v: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.receivers == 0 {
            return Err(SendError::Closed(v));
        }
        if let Some(cap) = st.cap {
            if st.items.len() >= cap {
                return Err(SendError::Closed(v));
            }
        }
        st.items.push_back(v);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel: pending items remain receivable, new sends fail.
    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Err(Closed)` once drained and no senders remain.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(v);
            }
            if st.closed || st.senders == 0 {
                return Err(RecvError::Closed);
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn try_recv(&self) -> Result<T, RecvError> {
        let mut st = self.inner.q.lock().unwrap();
        if let Some(v) = st.items.pop_front() {
            drop(st);
            self.inner.not_full.notify_one();
            return Ok(v);
        }
        if st.closed || st.senders == 0 {
            Err(RecvError::Closed)
        } else {
            Err(RecvError::Empty)
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(v);
            }
            if st.closed || st.senders == 0 {
                return Err(RecvError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Empty);
            }
            let (guard, _res) = self.inner.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain everything currently queued without blocking.
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.inner.q.lock().unwrap();
        let out: Vec<T> = st.items.drain(..).collect();
        drop(st);
        self.inner.not_full.notify_all();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn basic_send_recv() {
        let (tx, rx) = unbounded();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.try_recv(), Err(RecvError::Empty));
    }

    #[test]
    fn closed_after_senders_drop() {
        let (tx, rx) = unbounded();
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError::Closed));
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = unbounded::<u32>();
        drop(rx);
        assert!(matches!(tx.send(1), Err(SendError::Closed(1))));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = unbounded::<u64>();
        let producers = 4;
        let per = 1000u64;
        let mut handles = Vec::new();
        for p in 0..producers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort();
        assert_eq!(all, (0..producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_blocks_and_unblocks() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(matches!(tx.try_send(3), Err(SendError::Closed(3))));
        let t = {
            let tx = tx.clone();
            thread::spawn(move || tx.send(3).unwrap())
        };
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Ok(1));
        t.join().unwrap();
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.recv(), Ok(3));
    }

    #[test]
    fn recv_timeout_empty() {
        let (_tx, rx) = unbounded::<u32>();
        let start = Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(30)), Err(RecvError::Empty));
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn close_wakes_receivers() {
        let (tx, rx) = unbounded::<u32>();
        let h = thread::spawn(move || rx.recv());
        thread::sleep(Duration::from_millis(10));
        tx.close();
        assert_eq!(h.join().unwrap(), Err(RecvError::Closed));
    }

    #[test]
    fn duplex_pair_is_symmetric_and_closes_on_drop() {
        let (a, b) = duplex::<u32>();
        a.tx.send(1).unwrap();
        b.tx.send(2).unwrap();
        assert_eq!(b.rx.recv(), Ok(1));
        assert_eq!(a.rx.recv(), Ok(2));
        drop(b);
        assert_eq!(a.rx.recv(), Err(RecvError::Closed));
        assert!(matches!(a.tx.send(3), Err(SendError::Closed(3))));
    }

    #[test]
    fn drain_returns_pending() {
        let (tx, rx) = unbounded();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain(), vec![0, 1, 2, 3, 4]);
        assert!(rx.is_empty());
    }
}
