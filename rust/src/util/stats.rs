//! Small statistics toolkit shared by metrics, benchmarks and experiments:
//! running moments, percentiles, fixed-bin histograms and EWMA meters.

/// Welford running mean/variance — numerically stable single pass.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile with linear interpolation over a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Sorts a copy then takes percentiles; convenience for small samples.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Fixed-bin histogram over `[lo, hi)` with overflow/underflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.bins.len() as f64;
            let i = ((x - self.lo) / w) as usize;
            let i = i.min(self.bins.len() - 1);
            self.bins[i] += 1;
        }
    }

    /// Bin centres (for plotting / reporting).
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        (0..self.bins.len()).map(|i| self.lo + w * (i as f64 + 0.5)).collect()
    }

    /// Normalized frequencies per bin.
    pub fn density(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.bins.iter().map(|&c| c as f64 / n).collect()
    }
}

/// Exponentially-weighted moving average (per-interval rates like QPS).
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_two_pass() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 16.0);
        assert_eq!(r.count(), 5);
    }

    #[test]
    fn running_merge() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut a = Running::new();
        let mut b = Running::new();
        let mut whole = Running::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.var() - whole.var()).abs() < 1e-10);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 95.0) - 95.05).abs() < 1e-9);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.count, 12);
        assert!((h.centers()[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.push(10.0), 10.0);
        let mut last = 0.0;
        for _ in 0..20 {
            last = e.push(0.0);
        }
        assert!(last < 0.01);
    }
}
