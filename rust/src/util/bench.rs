//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module. Each
//! benchmark is measured with warmup, fixed-duration sampling, and reports
//! mean / p50 / p95 / std plus derived throughput. Results can be appended
//! to a JSON report for the experiment pipeline.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

pub use std::hint::black_box;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Max samples collected (each sample = one batched timing).
    pub max_samples: usize,
    /// Iterations per sample (auto-tuned if 0).
    pub iters_per_sample: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 200,
            iters_per_sample: 0,
        }
    }
}

impl BenchConfig {
    /// Fast profile for CI / tests.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            max_samples: 50,
            iters_per_sample: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration.
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Tail latency per iteration — the number a serving SLO watches.
    pub p99_ns: f64,
    pub std_ns: f64,
    pub samples: usize,
    pub total_iters: u64,
    /// Optional units processed per iteration (for throughput reporting).
    pub units_per_iter: f64,
}

impl BenchResult {
    /// Units per second (if `units_per_iter` set; else iterations/s).
    pub fn throughput(&self) -> f64 {
        let per_iter = if self.units_per_iter > 0.0 { self.units_per_iter } else { 1.0 };
        per_iter / (self.mean_ns * 1e-9)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("mean_ns", self.mean_ns)
            .set("p50_ns", self.p50_ns)
            .set("p95_ns", self.p95_ns)
            .set("p99_ns", self.p99_ns)
            .set("std_ns", self.std_ns)
            .set("samples", self.samples)
            .set("total_iters", self.total_iters)
            .set("throughput", self.throughput())
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_rate(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G/s", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M/s", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K/s", x / 1e3)
    } else {
        format!("{x:.1}/s")
    }
}

/// Benchmark runner collecting results for a report.
pub struct Bencher {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Self {
        // GBA_BENCH_QUICK=1 switches to the fast profile (used by `make test`).
        let cfg = if std::env::var("GBA_BENCH_QUICK").is_ok() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        Bencher { cfg, results: Vec::new() }
    }

    pub fn with_config(cfg: BenchConfig) -> Self {
        Bencher { cfg, results: Vec::new() }
    }

    /// Benchmark `f`, which should perform one logical iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_units(name, 1.0, f)
    }

    /// Benchmark with a throughput unit count per iteration (e.g. samples
    /// per batch) so the report shows units/s.
    pub fn bench_units<F: FnMut()>(&mut self, name: &str, units: f64, mut f: F) -> &BenchResult {
        // Warmup + auto-tune iterations per sample.
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.cfg.warmup || warm_iters == 0 {
            bb(&mut f)();
            warm_iters += 1;
        }
        let per_iter = self.cfg.warmup.as_nanos() as f64 / warm_iters.max(1) as f64;
        let iters = if self.cfg.iters_per_sample > 0 {
            self.cfg.iters_per_sample
        } else {
            // Aim for ~ (measure / max_samples) per sample.
            let target_ns = self.cfg.measure.as_nanos() as f64 / self.cfg.max_samples as f64;
            ((target_ns / per_iter.max(1.0)).ceil() as u64).max(1)
        };

        let mut samples = Vec::with_capacity(self.cfg.max_samples);
        let mut total_iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < self.cfg.measure && samples.len() < self.cfg.max_samples {
            let s = Instant::now();
            for _ in 0..iters {
                bb(&mut f)();
            }
            let ns = s.elapsed().as_nanos() as f64 / iters as f64;
            samples.push(ns);
            total_iters += iters;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile_sorted(&samples, 50.0),
            p95_ns: stats::percentile_sorted(&samples, 95.0),
            p99_ns: stats::percentile_sorted(&samples, 99.0),
            std_ns: stats::std(&samples),
            samples: samples.len(),
            total_iters,
            units_per_iter: units,
        };
        println!(
            "{:<48} {:>12} /iter  p50 {:>12}  p95 {:>12}  ±{:>10}  {:>12}",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
            fmt_ns(res.std_ns),
            fmt_rate(res.throughput()),
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Write collected results as a JSON report.
    pub fn write_report(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.to_string_pretty())
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_samples: 20,
            iters_per_sample: 0,
        });
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = acc.wrapping_add(black_box(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns * 1.5);
        assert!(r.samples > 0);
    }

    #[test]
    fn throughput_uses_units() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(10),
            max_samples: 5,
            iters_per_sample: 1,
        });
        let r = b.bench_units("sleepy", 100.0, || std::thread::sleep(Duration::from_micros(100)));
        let tp = r.throughput();
        // ~100 units / 100µs = ~1e6/s, allow wide margin.
        assert!(tp > 1e5 && tp < 2e7, "tp={tp}");
    }
}
