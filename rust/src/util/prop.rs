//! Seeded property-based testing helper (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs the closure `cases` times with
//! independent deterministic RNG streams; on panic it reports the failing
//! case index and its reproduction seed. A lightweight shrink step retries
//! failing cases with "smaller" sub-streams is intentionally omitted —
//! the per-case seed makes failures exactly reproducible, which is the
//! property we rely on in CI.

use super::rng::Pcg64;

/// Base seed; override with env `GBA_PROP_SEED` to explore other universes.
pub fn base_seed() -> u64 {
    std::env::var("GBA_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Number of cases; override with `GBA_PROP_CASES`.
pub fn case_count(default_cases: usize) -> usize {
    std::env::var("GBA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default_cases)
}

/// Run a property over `cases` random cases. The closure receives a
/// deterministic per-case RNG. Panics propagate with case context.
pub fn check<F: FnMut(&mut Pcg64)>(name: &str, cases: usize, mut prop: F) {
    let seed = base_seed();
    let cases = case_count(cases);
    for case in 0..cases {
        let mut rng = Pcg64::new(seed, case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with GBA_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Helpers for generating structured inputs.
pub mod gen {
    use super::Pcg64;

    /// Vec of length in `[lo, hi]` with elements from `f`.
    pub fn vec_of<T>(rng: &mut Pcg64, lo: usize, hi: usize, mut f: impl FnMut(&mut Pcg64) -> T) -> Vec<T> {
        let n = lo + rng.gen_range((hi - lo + 1) as u64) as usize;
        (0..n).map(|_| f(rng)).collect()
    }

    /// f32 in [-scale, scale], finite.
    pub fn f32_in(rng: &mut Pcg64, scale: f32) -> f32 {
        (rng.next_f32() * 2.0 - 1.0) * scale
    }

    /// usize in [lo, hi].
    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.gen_range((hi - lo + 1) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 25, |_rng| {
            count += 1;
        });
        assert_eq!(count, case_count(25));
    }

    #[test]
    fn failing_property_reports_case() {
        let result = std::panic::catch_unwind(|| {
            check("boom", 10, |rng| {
                // Fails deterministically on some case.
                assert!(rng.next_f64() < 0.9, "drew a large value");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property 'boom' failed"), "{msg}");
        assert!(msg.contains("GBA_PROP_SEED="), "{msg}");
    }

    #[test]
    fn gen_vec_bounds() {
        check("vec bounds", 50, |rng| {
            let v = gen::vec_of(rng, 2, 7, |r| r.next_u32());
            assert!(v.len() >= 2 && v.len() <= 7);
        });
    }
}
