//! Minimal JSON value model, writer and parser.
//!
//! Used for (a) parsing `artifacts/manifest.json` emitted by the python AOT
//! pipeline and (b) writing experiment/benchmark result files. No serde in
//! the offline environment, so this is a small self-contained implementation
//! covering the full JSON grammar (sufficient for our own round-trips).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Fluent insert for object construction.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no NaN/Inf; encode as null (documented lossy).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our files;
                            // map lone surrogates to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj()
            .set("name", "gba")
            .set("n", 42i64)
            .set("pi", 3.5f64)
            .set("ok", true)
            .set("none", Json::Null)
            .set("xs", vec![1i64, 2, 3]);
        let s = v.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj().set("a", Json::obj().set("b", vec!["x", "y"]));
        let back = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#"{"s": "a\nb\t\"c\" A"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn parse_numbers() {
        let v = parse("[-1, 2.5, 1e3, 1.5e-2, 0]").unwrap();
        let xs: Vec<f64> = v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(xs, vec![-1.0, 2.5, 1000.0, 0.015, 0.0]);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":{"b":[{"c":1},{"c":2}]}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().get("b").unwrap().idx(1).unwrap().get("c").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn escaped_string_roundtrip() {
        let v = Json::Str("line1\nline2\t\"quoted\"\\".into());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn nonfinite_serializes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }
}
