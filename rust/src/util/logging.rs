//! Tiny leveled logger. Level from `GBA_LOG` (error|warn|info|debug|trace),
//! default `info`. Timestamped to stderr; zero dependencies.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn init_level() -> u8 {
    let lvl = match std::env::var("GBA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok(other) => {
            // A typo'd GBA_LOG used to silently run at info; warn once
            // (init runs once — the 255 sentinel is only seen here)
            // naming the bad value so the operator sees why their
            // `GBA_LOG=dbug` run isn't any chattier.
            let mut err = std::io::stderr().lock();
            let _ = writeln!(
                err,
                "[WARN gba::util::logging] unrecognized GBA_LOG={other:?} \
                 (want error|warn|info|debug|trace); defaulting to info"
            );
            Level::Info
        }
        Err(_) => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_level() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{:>10}.{:03} {tag} {target}] {msg}", now.as_secs(), now.subsec_millis());
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
