//! Deterministic, seedable PRNG and the sampling distributions used across
//! the framework (data generation, straggler models, initialization).
//!
//! The build environment is offline (no `rand` crate), so this implements
//! PCG64 (O'Neill, 2014; the `pcg_xsl_rr_128_64` variant) plus the handful
//! of distributions the paper's workloads need: uniform, normal (Box–Muller),
//! lognormal, exponential, Bernoulli and bounded Zipf (the skewed ID
//! distribution of Fig. 4).

/// splitmix64 finalizer — cheap avalanche mix for deriving per-key seeds
/// (embedding lazy-init, teacher latents, shard selection).
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// PCG64: 128-bit LCG state, XSL-RR output function. Deterministic and
/// splittable via [`Pcg64::split`] so every worker / data shard / experiment
/// gets an independent stream from one experiment seed.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent generator; `tag` distinguishes children.
    pub fn split(&self, tag: u64) -> Self {
        // Use the current state to derive a new seed, mix in the tag.
        let s = (self.state >> 64) as u64 ^ (self.state as u64);
        Pcg64::new(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag.wrapping_add(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` single precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (no cached second value: keeps the
    /// stream position a pure function of draw count).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with parameters of the underlying normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -u.ln() / lambda
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Bounded Zipf(s) sampler over `{0, 1, .., n-1}` using the
/// rejection-inversion method (W. Hörmann & G. Derflinger, 1996). O(1) per
/// draw, supports s in (0, ..) including s=1. Rank 0 is the most frequent ID
/// — this is the skewed ID-occurrence distribution of Fig. 4.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "zipf over empty support");
        assert!(s > 0.0, "zipf exponent must be positive");
        let nf = n as f64;
        let h_x1 = Self::h(1.5, s) - 1.0;
        let h_n = Self::h(nf + 0.5, s);
        let dense = 1.0 / (Self::h_inv(h_x1, s) - Self::h_inv(h_x1 + 1e-12, s)).abs().max(1.0);
        Zipf { n: nf, s, h_x1, h_n, dense }
    }

    /// H(x) = integral of x^-s  (antiderivative, the s==1 case is ln).
    fn h(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-9 {
            x.ln()
        } else {
            (x.powf(1.0 - s) - 1.0) / (1.0 - s)
        }
    }

    fn h_inv(y: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-9 {
            y.exp()
        } else {
            (1.0 + y * (1.0 - s)).powf(1.0 / (1.0 - s))
        }
    }

    /// Draw a rank in `[0, n)`; rank 0 most probable.
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        let _ = self.dense;
        loop {
            let u = self.h_x1 + rng.next_f64() * (self.h_n - self.h_x1);
            let x = Self::h_inv(u, self.s);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            // Acceptance test.
            if k - x <= 0.5 || u >= Self::h(k + 0.5, self.s) - k.powf(-self.s) {
                return (k as u64) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_independent() {
        let root = Pcg64::seeded(9);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_unbiased_small() {
        let mut r = Pcg64::seeded(4);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.gen_range(5) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 5.0;
            assert!((c as f64 - expect).abs() < expect * 0.05, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::seeded(6);
        let n = 100_000;
        let lambda = 2.5;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Pcg64::seeded(7);
        let mut xs: Vec<f64> = (0..50_001).map(|_| r.lognormal(0.5, 0.8)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[25_000];
        assert!((median - 0.5f64.exp()).abs() < 0.05, "median={median}");
    }

    #[test]
    fn zipf_rank_ordering_and_support() {
        let mut r = Pcg64::seeded(8);
        let z = Zipf::new(1000, 1.2);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            counts[k as usize] += 1;
        }
        // Head must dominate the tail.
        assert!(counts[0] > counts[10] && counts[10] > counts[500]);
        // Rough check of the head mass against the analytic pmf.
        let hsum: f64 = (1..=1000u64).map(|k| (k as f64).powf(-1.2)).sum();
        let p0 = 1.0 / hsum;
        let f0 = counts[0] as f64 / 200_000.0;
        assert!((f0 - p0).abs() < 0.02, "f0={f0} p0={p0}");
    }

    #[test]
    fn zipf_s_equal_one() {
        let mut r = Pcg64::seeded(9);
        let z = Zipf::new(50, 1.0);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 50);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(10);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
