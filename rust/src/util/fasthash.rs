//! Fast hashing for u64 feature keys.
//!
//! std's default SipHash is DoS-resistant but ~5x slower than needed for
//! the embedding-store and gradient-aggregation hot paths, whose keys are
//! internal (not attacker-controlled). This hasher finalizes with the
//! splitmix64 avalanche — full 64-bit diffusion, one multiply-shift chain.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use super::rng::mix64;

/// Hasher specialized for a single `write_u64`/`write_usize` call.
#[derive(Default)]
pub struct U64Hasher {
    state: u64,
}

impl Hasher for U64Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = mix64(self.state ^ v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic fallback (unused on the hot path).
        for chunk in bytes.chunks(8) {
            let mut v = [0u8; 8];
            v[..chunk.len()].copy_from_slice(chunk);
            self.write_u64(u64::from_le_bytes(v));
        }
    }
}

pub type BuildU64Hasher = BuildHasherDefault<U64Hasher>;

/// HashMap keyed by u64 feature keys with the fast hasher.
pub type U64Map<V> = HashMap<u64, V, BuildU64Hasher>;

pub fn u64_map<V>() -> U64Map<V> {
    U64Map::default()
}

pub fn u64_map_with_capacity<V>(cap: usize) -> U64Map<V> {
    U64Map::with_capacity_and_hasher(cap, BuildU64Hasher::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m = u64_map();
        for k in 0..1000u64 {
            m.insert(k, k * 2);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&77], 154);
    }

    #[test]
    fn hash_is_diffuse() {
        use std::hash::BuildHasher;
        let bh = BuildU64Hasher::default();
        // Sequential keys must land on well-spread hashes (low-bit quality
        // matters for HashMap bucket selection).
        let mut low3 = [0usize; 8];
        for k in 0..8000u64 {
            let h = bh.hash_one(k);
            low3[(h & 7) as usize] += 1;
        }
        for &c in &low3 {
            assert!(c > 800 && c < 1200, "{low3:?}");
        }
    }
}
