//! Substrate utilities built in-repo (the build environment is offline, so
//! rand/serde/toml/criterion/proptest equivalents live here).

pub mod bench;
pub mod chan;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;
pub mod fasthash;
