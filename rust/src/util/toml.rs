//! TOML-subset parser for the config system.
//!
//! Supports the subset our configs use: `[table]` and `[nested.table]`
//! headers, `key = value` with string / integer / float / boolean / array
//! scalars, `#` comments and blank lines. Dotted keys in assignments and
//! array-of-tables are intentionally unsupported (configs don't need them);
//! the parser errors loudly instead of mis-reading.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }
    /// Floats accept integer literals too (`lr = 1` ≡ `1.0`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: map from `table.path.key` (dot-joined) to value.
/// Root-level keys have no prefix.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(|v| v.as_str())
    }
    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(|v| v.as_i64())
    }
    pub fn get_usize(&self, path: &str) -> Option<usize> {
        self.get(path).and_then(|v| v.as_usize())
    }
    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(|v| v.as_f64())
    }
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(|v| v.as_bool())
    }

    /// All keys under a table prefix (`prefix.` stripped).
    pub fn table_keys(&self, prefix: &str) -> Vec<String> {
        let pfx = format!("{prefix}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&pfx))
            .map(|k| k[pfx.len()..].to_string())
            .collect()
    }

    pub fn has_table(&self, prefix: &str) -> bool {
        !self.table_keys(prefix).is_empty()
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut prefix = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return Err(err("array-of-tables is not supported"));
            }
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated table header"))?;
            let name = name.trim();
            if name.is_empty() || !name.split('.').all(is_bare_key) {
                return Err(err("invalid table name"));
            }
            prefix = name.to_string();
        } else {
            let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
            let key = line[..eq].trim();
            if !is_bare_key(key) {
                return Err(err(&format!("invalid key '{key}' (dotted/quoted keys unsupported)")));
            }
            let vtext = line[eq + 1..].trim();
            let value = parse_value(vtext).map_err(|m| err(&m))?;
            let full = if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
            if doc.entries.insert(full.clone(), value).is_some() {
                return Err(err(&format!("duplicate key '{full}'")));
            }
        }
    }
    Ok(doc)
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s.strip_prefix('[').unwrap().strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    // Number: int unless it contains . e E (TOML floats).
    let cleaned = s.replace('_', "");
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        cleaned.parse::<f64>().map(TomlValue::Float).map_err(|_| format!("bad float '{s}'"))
    } else {
        cleaned.parse::<i64>().map(TomlValue::Int).map_err(|_| format!("bad value '{s}'"))
    }
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c == '"' {
            return Err("unescaped quote inside string".into());
        }
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                _ => return Err("bad escape".into()),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

/// Split on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_doc() {
        let doc = parse(
            r#"
# top comment
name = "criteo-deepfm"
seed = 42
lr = 1e-3

[model]
fields = 16
emb_dim = 16           # inline comment
hidden = [128, 64]
use_fm = true

[mode.gba]
iota = 3
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("criteo-deepfm"));
        assert_eq!(doc.get_i64("seed"), Some(42));
        assert_eq!(doc.get_f64("lr"), Some(1e-3));
        assert_eq!(doc.get_usize("model.fields"), Some(16));
        assert_eq!(doc.get_bool("model.use_fm"), Some(true));
        assert_eq!(doc.get_i64("mode.gba.iota"), Some(3));
        let hidden: Vec<i64> = doc
            .get("model.hidden")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(hidden, vec![128, 64]);
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("lr = 1").unwrap();
        assert_eq!(doc.get_f64("lr"), Some(1.0));
        assert_eq!(doc.get_i64("lr"), Some(1));
    }

    #[test]
    fn string_escapes_and_hash_in_string() {
        let doc = parse(r#"s = "a#b\nc""#).unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b\nc"));
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("x = [[1, 2], [3]]").unwrap();
        let arr = doc.get("x").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn errors() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("[[aot]]").is_err());
        assert!(parse("a.b = 1").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn table_keys_listing() {
        let doc = parse("[t]\na = 1\nb = 2\n[t2]\nc = 3").unwrap();
        let mut keys = doc.table_keys("t");
        keys.sort();
        assert_eq!(keys, vec!["a", "b"]);
        assert!(doc.has_table("t2"));
        assert!(!doc.has_table("missing"));
    }

    #[test]
    fn underscore_numbers() {
        let doc = parse("n = 1_000_000").unwrap();
        assert_eq!(doc.get_i64("n"), Some(1_000_000));
    }
}
