//! ISSUE 7 trajectory bench: embedding-gather throughput on a shard
//! whose primary connection is saturated by a stream of `Apply`s.
//!
//! Before this PR every read queued on the shard's single connection
//! behind whatever `Apply` was in flight (`call`, still measured here
//! as the "primary" row). The read-only companion connection
//! (`read_call`) lets gathers overlap the apply — the store's own
//! `RwLock`s become the only contention. The "idle" row is the
//! no-contention ceiling for reference.
//!
//!     cargo bench --bench bench_gather_overlap
//!
//! CI stores the JSON report as the `BENCH_7.json` trajectory artifact.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use gba::config::TransportKind;
use gba::embedding::EmbeddingConfig;
use gba::optim::Sgd;
use gba::runtime::HostTensor;
use gba::transport::{ShardReply, ShardRequest, ShardSpawnSpec, ShardSupervisor};
use gba::util::bench::{black_box, Bencher};

const DENSE_LEN: usize = 256;
const DIM: usize = 16;
const ROWS: u64 = 1024;
const GATHER_KEYS: usize = 256;
/// Embedding keys touched per apply — sized so one apply is meaty
/// enough that a queued gather actually waits on it.
const APPLY_KEYS: u64 = 512;

fn spec() -> ShardSpawnSpec {
    ShardSpawnSpec {
        index: 0,
        ranges: vec![(0, DENSE_LEN)],
        emb_cfg: EmbeddingConfig { dim: DIM, init_scale: 0.0, seed: 1, shards: 1 },
        opt_dense: Box::new(Sgd { lr: 1e-6 }),
        opt_emb: Box::new(Sgd { lr: 1e-6 }),
        addr: None,
        apply_threads: 1,
    }
}

fn apply_req() -> ShardRequest {
    ShardRequest::Apply {
        opt_step: 1,
        dense: vec![vec![1e-3; DENSE_LEN]],
        emb: (0..APPLY_KEYS).map(|k| (k % ROWS, vec![1e-3; DIM], 1)).collect(),
    }
}

fn gather_req() -> ShardRequest {
    ShardRequest::Gather { keys: (0..GATHER_KEYS as u64).map(|k| k * 3 % ROWS).collect() }
}

fn expect_rows(reply: ShardReply) {
    match reply {
        ShardReply::Rows { .. } => {}
        other => panic!("gather failed: {other:?}"),
    }
}

/// Run `f` while a background thread keeps the primary connection busy
/// with back-to-back applies.
fn under_applies<R>(sup: &Arc<ShardSupervisor>, f: impl FnOnce() -> R) -> R {
    let stop = Arc::new(AtomicBool::new(false));
    let handle = {
        let sup = sup.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match sup.call(0, apply_req()) {
                    ShardReply::Ok => {}
                    other => panic!("apply failed: {other:?}"),
                }
            }
        })
    };
    let r = f();
    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
    r
}

fn main() {
    let init = vec![HostTensor { shape: vec![DENSE_LEN], data: vec![0.0; DENSE_LEN] }];
    let sup = Arc::new(
        ShardSupervisor::start(
            TransportKind::Socket,
            vec![spec()],
            &init,
            std::time::Duration::from_secs(5),
        )
        .expect("starting shard supervisor"),
    );
    let rows = (0..ROWS).map(|k| (k, vec![0.5; DIM], vec![], Default::default())).collect();
    match sup.call(0, ShardRequest::InsertRows { rows }) {
        ShardReply::Ok => {}
        other => panic!("seeding rows failed: {other:?}"),
    }

    let mut b = Bencher::new();
    println!("-- {GATHER_KEYS}-key gathers vs a saturated apply stream (socket transport) --");
    b.bench_units("gather idle/primary", GATHER_KEYS as f64, || {
        expect_rows(black_box(sup.call(0, gather_req())));
    });
    under_applies(&sup, || {
        b.bench_units("gather under applies/primary (before)", GATHER_KEYS as f64, || {
            expect_rows(black_box(sup.call(0, gather_req())));
        });
    });
    under_applies(&sup, || {
        b.bench_units("gather under applies/companion (after)", GATHER_KEYS as f64, || {
            expect_rows(black_box(sup.read_call(0, gather_req())));
        });
    });
    b.write_report("results/bench_gather_overlap.json").ok();
}
