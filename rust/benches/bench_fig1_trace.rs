//! Fig. 1 bench: one full diurnal sweep (24 windows x 4 modes) of the
//! cluster simulator on the YouTubeDNN task — the end-to-end cost of
//! regenerating Fig. 1, and the per-window cost profile.
//!
//!     cargo bench --bench bench_fig1_trace

use gba::config::ModeKind;
use gba::experiments::{common, ExpCtx};
use gba::sim::simulate_mode;
use gba::util::bench::{black_box, Bencher};

fn main() {
    let ctx = ExpCtx::default();
    let cfg = common::load_task(&ctx, "private").expect("config");
    let mut b = Bencher::new();

    // Per-window cost at trough vs peak (event counts differ by load).
    for (label, hour) in [("trough 04:00", 4.0f64), ("peak 15:00", 15.0f64)] {
        for kind in [ModeKind::Sync, ModeKind::Async, ModeKind::Gba] {
            b.bench(&format!("window {label} {}", kind.as_str()), || {
                black_box(simulate_mode(&cfg, kind, hour * 3600.0, 60.0, 3));
            });
        }
    }

    // Whole-figure sweep.
    b.bench("full fig1 sweep (24h x 3 modes, 60s windows)", || {
        for h in 0..24 {
            for kind in [ModeKind::Sync, ModeKind::Async, ModeKind::Gba] {
                black_box(simulate_mode(&cfg, kind, h as f64 * 3600.0, 60.0, 3));
            }
        }
    });
    b.write_report("results/bench_fig1_trace.json").ok();
}
