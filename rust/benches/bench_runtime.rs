//! PJRT runtime bench: latency/throughput of the AOT train_step/predict
//! artifacts through the engine pool — the production compute path. Also
//! benchmarks the native backend on identical inputs for the backend
//! comparison recorded in EXPERIMENTS.md §Perf.
//!
//! Requires `make artifacts` (skips politely otherwise).
//!
//!     cargo bench --bench bench_runtime

use gba::model::NativeModel;
use gba::runtime::{EnginePool, HostTensor, Manifest};
use gba::util::bench::{black_box, Bencher};
use gba::util::rng::Pcg64;

fn rand_tensor(rng: &mut Pcg64, shape: Vec<usize>, scale: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::new(shape, (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()).unwrap()
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first; skipping");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let mut b = Bencher::new();

    for (variant, threads) in [("tiny", 1usize), ("small", 1), ("deepfm", 1)] {
        let Ok(dims) = manifest.dims(variant) else { continue };
        let Ok(batches) = manifest.batches(variant) else { continue };
        let batch = *batches.iter().max().unwrap();
        let pool = EnginePool::start(&manifest, variant, threads).expect("engine");
        let h = pool.handle();
        let mut rng = Pcg64::seeded(3);
        let emb = rand_tensor(&mut rng, vec![batch, dims.fields, dims.emb_dim], 0.3);
        let params: Vec<HostTensor> =
            dims.param_shapes().into_iter().map(|s| rand_tensor(&mut rng, s, 0.2)).collect();
        let labels: Vec<f32> = (0..batch).map(|i| (i % 2) as f32).collect();

        b.bench_units(&format!("pjrt train_step {variant} b{batch}"), batch as f64, || {
            black_box(
                h.train_step(batch, emb.clone(), params.clone(), labels.clone()).unwrap(),
            );
        });
        b.bench_units(&format!("pjrt predict {variant} b{batch}"), batch as f64, || {
            black_box(h.predict(batch, emb.clone(), params.clone()).unwrap());
        });

        let native = NativeModel::new(dims);
        b.bench_units(&format!("native train_step {variant} b{batch}"), batch as f64, || {
            black_box(native.train_step(&emb, &params, &labels));
        });
        pool.shutdown();
    }
    b.write_report("results/bench_runtime.json").ok();
}
