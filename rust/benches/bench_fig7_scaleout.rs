//! Fig. 7 bench: scale-out along both axes.
//!
//! 1. **Simulator scale-out** — event-processing throughput as the worker
//!    count grows from 100 to 800 at a fixed global batch (the paper's
//!    sweep). Checks the simulator itself scales near-linearly in events.
//! 2. **PS shard scale-out** — real `ShardedPs` push throughput as the
//!    parameter-server plane grows from 1 to 8 shards, under the async
//!    and GBA policies. The per-shard apply threads parallelize the dense
//!    optimizer sweep, so push throughput must be monotonically
//!    non-decreasing in the shard count.
//!
//!     cargo bench --bench bench_fig7_scaleout

use std::sync::Arc;

use gba::cluster::StragglerModel;
use gba::config::ClusterConfig;
use gba::coordinator::modes::{AsyncPolicy, GbaPolicy};
use gba::coordinator::ModePolicy;
use gba::embedding::EmbeddingConfig;
use gba::optim::Adam;
use gba::ps::{GradPush, PsServer, PullReply};
use gba::runtime::{HostTensor, VariantDims};
use gba::sim::{simulate, SimParams};
use gba::util::bench::{black_box, Bencher};

fn sim_scaleout(b: &mut Bencher) {
    let cluster = ClusterConfig {
        trace: "diurnal".into(),
        base_compute_ms: 8.0,
        hetero_sigma: 0.5,
        ps_apply_ms: 0.6,
        wire_ms: 0.0,
        workers: gba::config::WorkerPlane::InProc,
        worker_listen: String::new(),
    };
    let global = 400 * 1000;
    for workers in [100usize, 200, 400, 800] {
        let local = global / workers;
        let params = SimParams {
            workers,
            local_batch: local,
            compute: StragglerModel::new(&cluster, workers, 1),
            ps_apply_ms: cluster.ps_apply_ms,
            n_shards: 1,
            apply_threads: 1,
            wire_ms: 0.0,
            start_sec: 10.0 * 3600.0,
            duration_sec: 30.0,
            seed: workers as u64,
        };
        // Events processed per simulated run (batches pushed).
        let probe = simulate(&params, Box::new(GbaPolicy::with_iota(workers, 4)));
        let events: u64 = probe.per_worker_batches.iter().sum();
        b.bench_units(
            &format!("sim gba {workers}w x b{local} [vQPS {:.0}]", probe.global_qps()),
            events as f64,
            || {
                black_box(simulate(&params, Box::new(GbaPolicy::with_iota(workers, 4))));
            },
        );
    }
}

const PUSHERS: usize = 4;
const PUSHES_PER_THREAD: usize = 12;

fn bench_dims() -> VariantDims {
    // Medium dense tower (~172K parameters) so the optimizer apply — the
    // part the shards parallelize — dominates channel/lock overhead.
    VariantDims { fields: 16, emb_dim: 32, hidden1: 256, hidden2: 128, mlp_in: 16 * 32 + 32 }
}

fn make_ps(n_shards: usize, policy: Box<dyn ModePolicy>) -> Arc<PsServer> {
    let dims = bench_dims();
    let init: Vec<HostTensor> =
        dims.param_shapes().into_iter().map(HostTensor::zeros).collect();
    Arc::new(PsServer::with_shards(
        dims,
        init,
        EmbeddingConfig { dim: 32, init_scale: 0.01, seed: 5, shards: 8 },
        Box::new(Adam::new(0.001)),
        Box::new(Adam::new(0.001)),
        policy,
        n_shards,
    ))
}

fn template_push(worker: usize) -> GradPush {
    let dims = bench_dims();
    GradPush {
        worker,
        token: 0,
        dense: dims
            .param_shapes()
            .into_iter()
            .map(|s| {
                let n: usize = s.iter().product();
                HostTensor { shape: s, data: vec![1e-3; n] }
            })
            .collect(),
        emb: (0..32u64).map(|k| (worker as u64 * 1000 + k, vec![1e-3f32; 32])).collect(),
        n_samples: 32,
        loss: 0.69,
    }
}

/// One measured iteration: PUSHERS threads each pull+push a fixed batch
/// count through the shared PS front.
fn push_storm(ps: &Arc<PsServer>) {
    let mut handles = Vec::with_capacity(PUSHERS);
    for w in 0..PUSHERS {
        let ps = ps.clone();
        handles.push(std::thread::spawn(move || {
            let template = template_push(w);
            for _ in 0..PUSHES_PER_THREAD {
                let item = match ps.pull_blocking(w) {
                    PullReply::Work(item) => item,
                    other => panic!("unexpected pull reply {other:?}"),
                };
                let mut g = template.clone();
                g.token = item.token;
                ps.push(g);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

fn shard_scaleout(b: &mut Bencher) {
    let samples_per_iter = (PUSHERS * PUSHES_PER_THREAD * 32) as f64;
    let policies: [(&str, fn() -> Box<dyn ModePolicy>); 2] = [
        ("async", || Box::new(AsyncPolicy::new())),
        ("gba", || Box::new(GbaPolicy::with_iota(8, 4))),
    ];
    for (name, mk) in policies {
        let mut throughputs = Vec::new();
        for n_shards in [1usize, 2, 4, 8] {
            let ps = make_ps(n_shards, mk());
            ps.set_day(0, usize::MAX / 2);
            let r = b.bench_units(&format!("push {name} {n_shards}-shard ps"), samples_per_iter, || {
                push_storm(&ps);
            });
            throughputs.push((n_shards, r.throughput()));
            ps.flush_partial();
        }
        let base = throughputs[0].1;
        let summary: Vec<String> = throughputs
            .iter()
            .map(|(n, t)| format!("{n}-shard {:.2}x", t / base))
            .collect();
        println!("push scaling [{name}] vs 1 shard: {}", summary.join("  "));
    }
}

fn main() {
    let mut b = Bencher::new();
    sim_scaleout(&mut b);
    shard_scaleout(&mut b);
    b.write_report("results/bench_fig7_scaleout.json").ok();
}
