//! Fig. 7 bench: simulator scale-out — event-processing throughput as the
//! worker count grows from 100 to 800 at a fixed global batch (the paper's
//! sweep). Checks the simulator itself scales near-linearly in events.
//!
//!     cargo bench --bench bench_fig7_scaleout

use gba::cluster::StragglerModel;
use gba::config::ClusterConfig;
use gba::coordinator::modes::GbaPolicy;
use gba::sim::{simulate, SimParams};
use gba::util::bench::{black_box, Bencher};

fn main() {
    let cluster = ClusterConfig {
        trace: "diurnal".into(),
        base_compute_ms: 8.0,
        hetero_sigma: 0.5,
        ps_apply_ms: 0.6,
    };
    let global = 400 * 1000;
    let mut b = Bencher::new();
    for workers in [100usize, 200, 400, 800] {
        let local = global / workers;
        let params = SimParams {
            workers,
            local_batch: local,
            compute: StragglerModel::new(&cluster, workers, 1),
            ps_apply_ms: cluster.ps_apply_ms,
            start_sec: 10.0 * 3600.0,
            duration_sec: 30.0,
            seed: workers as u64,
        };
        // Events processed per simulated run (batches pushed).
        let probe = simulate(&params, Box::new(GbaPolicy::with_iota(workers, 4)));
        let events: u64 = probe.per_worker_batches.iter().sum();
        b.bench_units(
            &format!("sim gba {workers}w x b{local} [vQPS {:.0}]", probe.global_qps()),
            events as f64,
            || {
                black_box(simulate(&params, Box::new(GbaPolicy::with_iota(workers, 4))));
            },
        );
    }
    b.write_report("results/bench_fig7_scaleout.json").ok();
}
