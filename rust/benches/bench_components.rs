//! Component micro-benchmarks: the L3 hot paths identified in DESIGN.md
//! §Perf — gradient aggregation, embedding store, per-ID reduce, policy
//! state machines, AUC, and the substrate (rng/channel).
//!
//!     cargo bench --bench bench_components

use gba::coordinator::modes::GbaPolicy;
use gba::coordinator::ModePolicy;
use gba::data::DataGen;
use gba::embedding::{EmbeddingConfig, EmbeddingStore};
use gba::metrics::auc;
use gba::model::NativeModel;
use gba::optim::{Adagrad, Adam, Optimizer};
use gba::ps::reduce_emb_grads;
use gba::runtime::{HostTensor, VariantDims};
use gba::util::bench::{black_box, Bencher};
use gba::util::chan;
use gba::util::rng::{Pcg64, Zipf};

fn main() {
    let mut b = Bencher::new();

    // --- substrate -------------------------------------------------------
    let mut rng = Pcg64::seeded(1);
    b.bench("rng::next_u64", || {
        black_box(rng.next_u64());
    });
    let zipf = Zipf::new(1_000_000, 1.1);
    b.bench("rng::zipf_sample(1M, s=1.1)", || {
        black_box(zipf.sample(&mut rng));
    });
    {
        let (tx, rx) = chan::unbounded::<u64>();
        b.bench("chan::send+recv (uncontended)", || {
            tx.send(1).unwrap();
            black_box(rx.try_recv().unwrap());
        });
    }

    // --- data generation --------------------------------------------------
    let model_cfg = gba::config::ModelConfig {
        variant: "deepfm".into(),
        fields: 16,
        emb_dim: 16,
        hidden1: 128,
        hidden2: 64,
        vocab_size: 200_000,
        zipf_s: 1.1,
    };
    let data_cfg = gba::config::DataConfig {
        days_base: 1,
        days_eval: 1,
        samples_per_day: 1 << 20,
        teacher_seed: 7,
        label_noise: 0.05,
        drift: 0.01,
    };
    let gen = DataGen::new(&model_cfg, &data_cfg, 3);
    let mut bi = 0usize;
    b.bench_units("data::batch_by_index(B=256,F=16)", 256.0, || {
        bi += 1;
        black_box(gen.batch_by_index(0, bi % 1000, 256));
    });

    // --- embedding store ---------------------------------------------------
    let store = EmbeddingStore::new(
        EmbeddingConfig { dim: 16, init_scale: 0.05, seed: 5, shards: 16 },
        1,
    );
    let batch = gen.batch_by_index(0, 0, 256);
    b.bench_units("embedding::gather(256x16 keys)", (256 * 16) as f64, || {
        black_box(store.gather(&batch.keys, 256, 16));
    });
    let opt = Adagrad::new(0.01);
    let grads: Vec<(u64, Vec<f32>, u32)> =
        batch.keys.iter().take(512).map(|&k| (k, vec![0.01f32; 16], 2)).collect();
    b.bench_units("embedding::apply_grads(512 ids)", 512.0, || {
        store.apply_grads(&grads, &opt, 1);
    });

    // --- per-ID gradient reduce (worker-side) ------------------------------
    let d_emb = HostTensor::zeros(vec![256 * 16, 16]);
    b.bench_units("ps::reduce_emb_grads(256x16)", (256 * 16) as f64, || {
        black_box(reduce_emb_grads(&batch.keys, &d_emb));
    });

    // --- policy state machines ---------------------------------------------
    let mut gba_policy = GbaPolicy::with_iota(100, 4);
    b.bench("policy::gba pull+push cycle", || {
        let _ = gba_policy.on_pull(0);
        if let gba::coordinator::PushAction::FlushNow = gba_policy.on_push(0, 0) {
            let tokens: Vec<u64> = (0..100).collect();
            black_box(gba_policy.flush_spec(&tokens));
            gba_policy.on_applied();
        }
    });

    // --- optimizers ---------------------------------------------------------
    let adam = Adam::new(0.001);
    let n = 64 * 1024;
    let mut p = vec![0.1f32; n];
    let g = vec![0.01f32; n];
    let mut s = vec![0.0f32; 2 * n];
    let mut t = 0;
    b.bench_units("optim::adam(64K params)", n as f64, || {
        t += 1;
        adam.apply(&mut p, &g, &mut s, t);
    });

    // --- native model train_step --------------------------------------------
    let dims = VariantDims { fields: 16, emb_dim: 16, hidden1: 128, hidden2: 64, mlp_in: 272 };
    let native = NativeModel::new(dims);
    let params = native.init_params(1);
    let mut r2 = Pcg64::seeded(2);
    let emb = HostTensor::new(
        vec![256, 16, 16],
        (0..256 * 256).map(|_| r2.next_f32() - 0.5).collect(),
    )
    .unwrap();
    let labels: Vec<f32> = (0..256).map(|i| (i % 2) as f32).collect();
    b.bench_units("model::native_train_step(B=256 deepfm)", 256.0, || {
        black_box(native.train_step(&emb, &params, &labels));
    });

    // --- metrics -------------------------------------------------------------
    let scores: Vec<f32> = (0..10_000).map(|_| r2.next_f32()).collect();
    let labels2: Vec<f32> = (0..10_000).map(|_| (r2.bernoulli(0.3)) as u8 as f32).collect();
    b.bench_units("metrics::auc(10K)", 10_000.0, || {
        black_box(auc(&scores, &labels2));
    });

    b.write_report("results/bench_components.json").ok();
}
