//! ISSUE 8 trajectory bench: the shard apply hot path.
//!
//! Measures the three surfaces the PR accelerates, straight on a
//! [`PsShard`] (no transport, so wire cost can't mask kernel cost):
//!
//!  * dense sweep — one aggregated dense apply per optimizer kind ×
//!    `apply_threads` × tensor size. The chunked kernels set the
//!    single-thread floor; the row-sharded fan-out sets the scaling.
//!  * embedding sweep — lock-shard-grouped `apply_grads` at growing
//!    key counts, threads 1 vs 8. One `RwLock` acquisition per
//!    lock-shard per apply instead of one per key.
//!  * wire sweep — the same apply and a bulk gather as full RPCs
//!    against a `ShardService` over a localhost socket, so the report
//!    shows how much of the end-to-end step the codec + kernel leave on
//!    the table (and what the scatter/gather streaming reply encode is
//!    worth on the gather side).
//!
//! Every configuration is bit-identical to `apply_threads = 1` by the
//! pins in `shard::tests` and `optim::tests`; this bench only asks how
//! fast the identical answer arrives.
//!
//!     cargo bench --bench bench_apply_hotpath
//!
//! CI stores the JSON report as the `BENCH_8.json` trajectory artifact.

use gba::embedding::EmbeddingConfig;
use gba::optim::{Adagrad, Adam, Optimizer, Sgd};
use gba::runtime::HostTensor;
use gba::shard::PsShard;
use gba::transport::codec::{ShardReply, ShardRequest};
use gba::transport::endpoint::{rpc, SocketConn};
use gba::transport::service::{serve, ShardService};
use gba::util::bench::{black_box, Bencher};
use gba::util::rng::Pcg64;

/// Dense tensor sizes: one comfortably sub-fan-out (serial path), one
/// around the crossover, one where 8 workers each get a real slice.
const DENSE_SIZES: [usize; 3] = [4_096, 65_536, 1_048_576];
const THREADS: [usize; 2] = [1, 8];
const EMB_DIM: usize = 16;
const EMB_KEY_COUNTS: [usize; 3] = [256, 2_048, 16_384];

fn optimizers() -> Vec<(&'static str, Box<dyn Optimizer>)> {
    vec![
        ("sgd", Box::new(Sgd { lr: 1e-6 }) as Box<dyn Optimizer>),
        ("adagrad", Box::new(Adagrad::new(1e-6))),
        ("adam", Box::new(Adam::new(1e-6))),
    ]
}

fn dense_shard(n: usize, dense_slots: usize, threads: usize) -> PsShard {
    let init = HostTensor { shape: vec![n], data: vec![0.1; n] };
    PsShard::new(
        0,
        vec![(0, n)],
        std::slice::from_ref(&init),
        dense_slots,
        EmbeddingConfig { dim: EMB_DIM, init_scale: 0.0, seed: 7, shards: 8 },
        dense_slots,
        threads,
    )
}

fn dense_grad(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2e-4 - 1e-4).collect()
}

fn emb_group(rng: &mut Pcg64, keys: usize) -> Vec<(u64, Vec<f32>, u32)> {
    (0..keys as u64)
        .map(|k| (k * 3, (0..EMB_DIM).map(|_| rng.next_f32() * 2e-4 - 1e-4).collect(), 1))
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::seeded(80);

    println!("-- dense apply: optimizer kind x apply_threads x tensor size --");
    for (name, opt) in optimizers() {
        for &n in &DENSE_SIZES {
            let grad = dense_grad(&mut rng, n);
            for &threads in &THREADS {
                let shard = dense_shard(n, opt.slots(), threads);
                let dense = vec![grad.clone()];
                let mut step = 0u64;
                b.bench_units(&format!("dense/{name} n={n} threads={threads}"), n as f64, || {
                    step += 1;
                    shard.apply(black_box(&dense), &[], opt.as_ref(), opt.as_ref(), step);
                });
            }
        }
    }

    println!("-- embedding apply: lock-shard-grouped, key count x apply_threads --");
    let opt = Adam::new(1e-6);
    for &keys in &EMB_KEY_COUNTS {
        let group = emb_group(&mut rng, keys);
        for &threads in &THREADS {
            // Tiny dense side so the embedding group dominates the apply.
            let shard = dense_shard(64, opt.slots(), threads);
            let dense = vec![vec![0.0f32; 64]];
            let mut step = 0u64;
            b.bench_units(&format!("emb/keys={keys} threads={threads}"), keys as f64, || {
                step += 1;
                shard.apply(black_box(&dense), black_box(&group), &opt, &opt, step);
            });
        }
    }

    println!("-- wire transport: the same verbs as full RPCs over a localhost socket --");
    {
        let n = 65_536;
        let keys = 2_048usize;
        let opt = Adam::new(1e-6);
        let shard = dense_shard(n, opt.slots(), 1);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            // Exits when the client drops its connection.
            serve(
                ShardService::new(shard, Box::new(Adam::new(1e-6)), Box::new(Adam::new(1e-6))),
                Box::new(SocketConn::new(stream)),
            );
        });
        let mut conn = SocketConn::new(std::net::TcpStream::connect(addr).unwrap());

        let grad = dense_grad(&mut rng, n);
        let group = emb_group(&mut rng, keys);
        let mut step = 0u64;
        b.bench_units(&format!("wire/apply n={n} keys={keys}"), (n + keys) as f64, || {
            step += 1;
            let reply = rpc(
                &mut conn,
                ShardRequest::Apply {
                    opt_step: step,
                    dense: vec![black_box(grad.clone())],
                    emb: black_box(group.clone()),
                },
            )
            .unwrap();
            assert!(matches!(reply, ShardReply::Ok));
        });

        let gather_keys: Vec<u64> = (0..keys as u64).map(|k| k * 3).collect();
        b.bench_units(&format!("wire/gather keys={keys}"), keys as f64, || {
            let reply =
                rpc(&mut conn, ShardRequest::Gather { keys: black_box(gather_keys.clone()) })
                    .unwrap();
            match reply {
                ShardReply::Rows { dim, data } => {
                    assert_eq!(data.len(), gather_keys.len() * dim as usize);
                }
                other => panic!("expected Rows, got {other:?}"),
            }
        });

        drop(conn);
        server.join().unwrap();
    }

    b.write_report("results/bench_apply_hotpath.json").ok();
}
