//! Fault injection: kill a PS shard endpoint mid-epoch and pin the
//! recovery semantics (ISSUE 2 acceptance).
//!
//! The deterministic core drives the GBA pull/push sequence from a
//! single thread and severs one shard's endpoint between two pushes of a
//! global batch — the kill is synchronized by *program order*, not
//! sleeps. The supervisor must detect the dead endpoint at the next
//! apply, respawn the shard from its shard-local checkpoint, replay the
//! journal (which re-admits the affected global batch), and training
//! must complete with results matching a no-failure run. Because the
//! journal replay is exact, the match is bit-for-bit — strictly stronger
//! than the staleness-decay tolerance the control plane would forgive.
//!
//! A threaded smoke test additionally kills a shard while worker threads
//! are concurrently pushing (synchronized by spinning on the observable
//! global step, again no sleeps) and asserts the control plane's
//! conservation law: every batch is applied or dropped, never lost.

use std::sync::Arc;

use gba::config::TransportKind;
use gba::coordinator::modes::GbaPolicy;
use gba::embedding::EmbeddingConfig;
use gba::metrics::TrainCounters;
use gba::optim::Adam;
use gba::ps::{GradPush, PullReply};
use gba::runtime::{HostTensor, VariantDims};
use gba::shard::{PsBuild, ShardedPs};

const N_SHARDS: usize = 3;

fn dims() -> VariantDims {
    VariantDims { fields: 2, emb_dim: 4, hidden1: 6, hidden2: 4, mlp_in: 12 }
}

fn init_params() -> Vec<HostTensor> {
    dims()
        .param_shapes()
        .into_iter()
        .enumerate()
        .map(|(t, s)| {
            let n: usize = s.iter().product();
            HostTensor {
                shape: s,
                data: (0..n).map(|i| 0.3 + t as f32 * 0.07 + i as f32 * 0.013).collect(),
            }
        })
        .collect()
}

fn grad(token: u64, keys: &[u64], g: f32) -> GradPush {
    GradPush {
        worker: 0,
        token,
        dense: dims()
            .param_shapes()
            .into_iter()
            .map(|s| {
                let n: usize = s.iter().product();
                HostTensor { shape: s, data: (0..n).map(|i| g + i as f32 * 1e-3).collect() }
            })
            .collect(),
        emb: keys.iter().map(|&k| (k, vec![g; 4])).collect(),
        n_samples: 8,
        loss: 0.5 + g * 0.1,
    }
}

fn build(transport: TransportKind) -> ShardedPs {
    PsBuild {
        dims: dims(),
        init_params: init_params(),
        emb_cfg: EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 17, shards: 2 },
        opt_dense: Box::new(Adam::new(0.01)),
        opt_emb: Box::new(Adam::new(0.01)),
        policy: Box::new(GbaPolicy::with_iota(2, 3)),
        n_shards: N_SHARDS,
        transport,
        shard_addrs: Vec::new(),
        connect_deadline: None,
        apply_threads: 1,
    }
    .build()
}

struct EpochResult {
    dense_bits: Vec<Vec<u32>>,
    rows_bits: Vec<Vec<u32>>,
    loss_curve: Vec<(u64, f32)>,
    counters: TrainCounters,
    lost_events: u64,
}

/// Drive 10 GBA global batches (M = 2) plus one partial flush. With
/// `kill = Some(shard)`, shard `shard` is killed after the *first* push
/// of global batch 5 — mid-epoch, mid-global-batch: the flush completing
/// that batch is the one that discovers the corpse.
fn run_epoch(transport: TransportKind, kill: Option<usize>) -> EpochResult {
    let keys: Vec<u64> = (0..32).map(|i| i * 104_729 + 11).collect();
    let ps = build(transport);
    // Small cadence so the run exercises checkpoint refresh + journal
    // truncation before the kill, not just the initial checkpoint.
    ps.set_shard_ckpt_every(2);
    ps.set_day(0, 1000);
    for step in 0..10u64 {
        for j in 0..2u64 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            let g = 0.2 + step as f32 * 0.03 + j as f32 * 0.01;
            ps.push(grad(it.token, &keys[..(6 + step as usize)], g));
            if step == 5 && j == 0 {
                if let Some(shard) = kill {
                    ps.kill_shard(shard);
                }
            }
        }
    }
    // End-of-day partial flush (one buffered grad).
    let it = match ps.pull(0) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    ps.push(grad(it.token, &keys[..4], 0.9));
    assert!(ps.flush_partial());
    assert!(ps.quiescent());

    let dense_bits = ps
        .dense_params()
        .into_iter()
        .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
        .collect();
    let rows_bits = keys
        .iter()
        .map(|&k| ps.emb_row(k).iter().map(|x| x.to_bits()).collect())
        .collect();
    EpochResult {
        dense_bits,
        rows_bits,
        loss_curve: ps.loss_curve(),
        counters: ps.counters(),
        lost_events: ps.lost_shard_events(),
    }
}

fn assert_recovered(clean: &EpochResult, faulty: &EpochResult) {
    assert_eq!(clean.lost_events, 0, "clean run must not recover anything");
    assert_eq!(faulty.lost_events, 1, "exactly one lost-shard recovery");
    // Training completed identically as far as the control plane is
    // concerned: same steps, same applied/dropped accounting, same loss
    // curve — the failure never leaked into token control.
    assert_eq!(faulty.counters.global_steps, clean.counters.global_steps);
    assert_eq!(faulty.counters.applied_gradients, clean.counters.applied_gradients);
    assert_eq!(faulty.counters.dropped_batches, clean.counters.dropped_batches);
    assert_eq!(faulty.loss_curve, clean.loss_curve);
    // Dense parameters on *every* shard — survivors and the respawned
    // one — match the no-failure run exactly (journal replay is exact,
    // which is within any staleness-decay tolerance).
    assert_eq!(faulty.dense_bits, clean.dense_bits, "dense params diverged after recovery");
    assert_eq!(faulty.rows_bits, clean.rows_bits, "embedding rows diverged after recovery");
}

#[test]
fn killed_shard_recovers_bit_identically_inproc() {
    let clean = run_epoch(TransportKind::InProc, None);
    let faulty = run_epoch(TransportKind::InProc, Some(1));
    assert_recovered(&clean, &faulty);
}

#[test]
fn killed_shard_recovers_bit_identically_socket() {
    let clean = run_epoch(TransportKind::Socket, None);
    let faulty = run_epoch(TransportKind::Socket, Some(1));
    assert_recovered(&clean, &faulty);
}

#[test]
fn killing_every_shard_in_turn_is_survivable() {
    let clean = run_epoch(TransportKind::InProc, None);
    for shard in 0..N_SHARDS {
        let faulty = run_epoch(TransportKind::InProc, Some(shard));
        assert_recovered(&clean, &faulty);
    }
}

/// ROADMAP follow-up (e): with `[ps] journal_spill_bytes` set, a long
/// checkpoint cadence keeps the journal on disk instead of in memory —
/// and a kill must replay the spilled segment plus the in-memory tail
/// to the exact same state as the never-spilling run.
#[test]
fn journal_spill_to_disk_replays_bit_identically() {
    let keys: Vec<u64> = (0..16).map(|i| i * 104_729 + 11).collect();
    let drive = |spill_bytes: usize| {
        let ps = build(TransportKind::InProc);
        // Cadence far beyond the epoch: nothing truncates the journal,
        // so with a tiny cap the spill path must engage.
        ps.set_shard_ckpt_every(1_000_000);
        ps.set_journal_spill_bytes(spill_bytes);
        ps.set_day(0, 1000);
        for step in 0..8u64 {
            for j in 0..2u64 {
                let it = match ps.pull(0) {
                    PullReply::Work(it) => it,
                    other => panic!("{other:?}"),
                };
                ps.push(grad(it.token, &keys[..(4 + step as usize)], 0.2 + step as f32 * 0.03 + j as f32 * 0.01));
            }
        }
        if spill_bytes > 0 {
            assert!(
                (0..N_SHARDS).any(|s| ps.journal_spilled_frames(s) > 0),
                "spill cap of {spill_bytes} bytes never engaged"
            );
        }
        // Kill one shard: recovery replays the whole journal (disk
        // segment first, then the tail) from the initial checkpoint.
        ps.kill_shard(1);
        let dense: Vec<Vec<u32>> = ps
            .dense_params()
            .into_iter()
            .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
            .collect();
        let rows: Vec<Vec<u32>> = keys
            .iter()
            .map(|&k| ps.emb_row(k).iter().map(|x| x.to_bits()).collect())
            .collect();
        (dense, rows, ps.lost_shard_events())
    };
    let in_memory = drive(0);
    let spilled = drive(128);
    assert_eq!(in_memory.2, 1);
    assert_eq!(spilled.2, 1);
    assert_eq!(spilled.0, in_memory.0, "dense params diverged after spilled replay");
    assert_eq!(spilled.1, in_memory.1, "embedding rows diverged after spilled replay");
}

/// The lost-token path composes with the lost-shard path: a worker whose
/// claim was in flight when the shard died resets (Appendix B), and the
/// control plane neither wedges nor leaks the claim.
#[test]
fn worker_reset_after_shard_kill_keeps_control_plane_sane() {
    let ps = build(TransportKind::InProc);
    ps.set_day(0, 100);
    let keys = [3u64, 5, 8];
    // Two claims out; one full global batch applied.
    let a = match ps.pull(0) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    let b = match ps.pull(1) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    ps.push(grad(a.token, &keys, 0.1));
    ps.push(grad(b.token, &keys, 0.2));
    assert_eq!(ps.global_step(), 1);
    // Worker 1 pulls, the shard dies, the worker dies with its claim.
    let c = match ps.pull(1) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    ps.kill_shard(2);
    ps.worker_reset(1);
    assert_eq!(ps.outstanding(), 0);
    // Training continues: the next full batch flushes through recovery.
    // The reset claim's batch index is *re-issued* (end-of-day coverage
    // stays complete), so the next pull picks it up first.
    let d = match ps.pull(0) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    let e = match ps.pull(0) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    assert_eq!(c.batch_index, d.batch_index, "reset claim's batch re-issued first");
    assert_ne!(d.batch_index, e.batch_index);
    assert_eq!(ps.counters().reissued_batches, 1);
    ps.push(grad(d.token, &keys, 0.3));
    ps.push(grad(e.token, &keys, 0.4));
    assert_eq!(ps.global_step(), 2);
    assert_eq!(ps.lost_shard_events(), 1);
    assert!(ps.quiescent());
    // The respawned shard serves reads again.
    let _ = ps.dense_params();
    let _ = ps.emb_row(5);
}

/// Concurrent workers + a mid-training kill (synchronized by spinning on
/// the global step — no sleeps): the control plane's conservation law
/// holds and the PS stays serviceable.
#[test]
fn concurrent_training_survives_shard_kill() {
    let ps = Arc::new(build(TransportKind::InProc));
    let n_batches = 120usize;
    ps.set_day(0, n_batches);
    let mut workers = Vec::new();
    for w in 0..2usize {
        let ps = ps.clone();
        workers.push(std::thread::spawn(move || {
            let keys: Vec<u64> = (0..8).map(|i| (w as u64) * 1000 + i * 37).collect();
            let mut pushed = 0u64;
            loop {
                let it = match ps.pull_blocking(w) {
                    PullReply::Work(it) => it,
                    PullReply::EndOfData => break,
                    PullReply::Wait => unreachable!(),
                };
                ps.push(grad(it.token, &keys, 0.05 + w as f32 * 0.01));
                pushed += 1;
            }
            pushed
        }));
    }
    let killer = {
        let ps = ps.clone();
        std::thread::spawn(move || {
            while ps.global_step() < 3 {
                std::thread::yield_now();
            }
            ps.kill_shard(0);
        })
    };
    let pushed: u64 = workers.into_iter().map(|h| h.join().unwrap()).sum();
    killer.join().unwrap();
    ps.flush_partial();
    assert_eq!(pushed, n_batches as u64);
    let c = ps.counters();
    assert_eq!(
        c.applied_gradients + c.dropped_batches,
        n_batches as u64,
        "a batch was lost rather than applied or dropped"
    );
    assert!(c.global_steps > 0);
    assert!(ps.quiescent());
    // Post-kill the full read surface still works; these reads touch
    // every shard, so if the kill landed after the last flush the
    // recovery happens here — either way, exactly one by the end.
    let p = ps.dense_params();
    assert_eq!(p.len(), 6);
    assert!(ps.emb_len() > 0);
    assert_eq!(ps.lost_shard_events(), 1);
}
