//! Shard-count and transport invariance of the control plane: GBA
//! training on a 1-shard and a 4-shard parameter-server plane — and on
//! in-process vs. localhost-TCP shard endpoints — must produce
//! *identical* results for the same seed. The token-control state is
//! shard-global, dense aggregation happens once, the per-shard optimizer
//! apply is elementwise, and the wire codec carries `f32`s as raw bits,
//! so nothing may depend on `n_shards` or on `[ps] transport`.
//!
//! Determinism note: the sessions run a single worker thread, so the
//! pull/push sequence (and therefore the buffer composition of every
//! global batch) is identical across runs; any divergence would have to
//! come from the sharded data plane or the transport itself.

use gba::config::{ExperimentConfig, ModeKind};
use gba::worker::session::{SessionOptions, TrainSession};

fn cfg(n_shards: usize) -> ExperimentConfig {
    cfg_with_transport(n_shards, "inproc")
}

fn cfg_with_transport(n_shards: usize, transport: &str) -> ExperimentConfig {
    ExperimentConfig::from_toml(&format!(
        r#"
name = "shard-invariance"
seed = 1234
[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 32
hidden2 = 16
vocab_size = 3000
zipf_s = 1.1
[data]
days_base = 1
days_eval = 1
samples_per_day = 2048
teacher_seed = 9
label_noise = 0.02
[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
eval_batch = 256
eval_samples = 1024
[ps]
n_shards = {n_shards}
transport = "{transport}"
[mode.sync]
workers = 2
local_batch = 64
[mode.gba]
workers = 1
local_batch = 32
iota = 3
"#
    ))
    .unwrap()
}

struct RunResult {
    loss_curve: Vec<(u64, f32)>,
    dense_bits: Vec<Vec<u32>>,
    global_steps: u64,
    auc: f64,
}

fn run_gba_day(n_shards: usize) -> RunResult {
    run_gba_day_over(n_shards, "inproc")
}

fn run_gba_day_over(n_shards: usize, transport: &str) -> RunResult {
    let s = TrainSession::new(
        cfg_with_transport(n_shards, transport),
        ModeKind::Gba,
        SessionOptions::default(),
    )
    .unwrap();
    assert_eq!(s.ps().n_shards(), n_shards);
    assert_eq!(s.ps().transport().as_str(), transport);
    let stats = s.train_day(0).unwrap();
    let dense_bits = s
        .ps()
        .dense_params()
        .into_iter()
        .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
        .collect();
    RunResult {
        loss_curve: s.ps().loss_curve(),
        dense_bits,
        global_steps: stats.counters.global_steps,
        auc: s.eval_auc(1).unwrap(),
    }
}

#[test]
fn gba_identical_loss_curves_on_1_and_4_shards() {
    let one = run_gba_day(1);
    let four = run_gba_day(4);

    assert!(one.global_steps > 10, "run too short to be meaningful");
    assert_eq!(one.global_steps, four.global_steps);
    assert_eq!(
        one.loss_curve.len(),
        four.loss_curve.len(),
        "different number of applies across shard counts"
    );
    for (i, (a, b)) in one.loss_curve.iter().zip(&four.loss_curve).enumerate() {
        assert_eq!(a.0, b.0, "apply {i}: global step differs");
        assert_eq!(
            a.1.to_bits(),
            b.1.to_bits(),
            "apply {i}: loss differs ({} vs {})",
            a.1,
            b.1
        );
    }
    // Bit-for-bit identical dense parameters after the day.
    assert_eq!(one.dense_bits, four.dense_bits, "dense parameters diverged");
    assert!(
        (one.auc - four.auc).abs() < 1e-12,
        "AUC diverged: {} vs {}",
        one.auc,
        four.auc
    );
    assert!(one.auc > 0.55, "training should beat chance, auc = {}", one.auc);
}

/// Acceptance criterion: `--transport socket` end-to-end results are
/// identical to `--transport inproc` — bit-for-bit, down to the loss
/// curve and the final dense parameters.
#[test]
fn gba_identical_results_inproc_vs_socket() {
    let inproc = run_gba_day_over(4, "inproc");
    let socket = run_gba_day_over(4, "socket");

    assert!(inproc.global_steps > 10, "run too short to be meaningful");
    assert_eq!(inproc.global_steps, socket.global_steps);
    assert_eq!(
        inproc.loss_curve.len(),
        socket.loss_curve.len(),
        "different number of applies across transports"
    );
    for (i, (a, b)) in inproc.loss_curve.iter().zip(&socket.loss_curve).enumerate() {
        assert_eq!(a.0, b.0, "apply {i}: global step differs");
        assert_eq!(
            a.1.to_bits(),
            b.1.to_bits(),
            "apply {i}: loss differs across transports ({} vs {})",
            a.1,
            b.1
        );
    }
    assert_eq!(inproc.dense_bits, socket.dense_bits, "dense parameters diverged over the wire");
    assert!(
        (inproc.auc - socket.auc).abs() < 1e-12,
        "AUC diverged: {} vs {}",
        inproc.auc,
        socket.auc
    );
}

/// And the single-shard degenerate case: one shard behind a socket is
/// still the seed server, byte for byte.
#[test]
fn single_shard_socket_matches_inproc() {
    let inproc = run_gba_day_over(1, "inproc");
    let socket = run_gba_day_over(1, "socket");
    assert_eq!(inproc.dense_bits, socket.dense_bits);
    assert_eq!(inproc.global_steps, socket.global_steps);
}

#[test]
fn sharded_checkpoint_inherits_across_shard_counts() {
    // Train on 4 shards, checkpoint, restore into a 1-shard session: the
    // evaluation must be identical (parameters are shard-layout-free).
    let four = TrainSession::new(cfg(4), ModeKind::Gba, SessionOptions::default()).unwrap();
    four.train_day(0).unwrap();
    let auc_four = four.eval_auc(1).unwrap();
    let ckpt = four.checkpoint();

    let one =
        TrainSession::from_checkpoint(cfg(1), ModeKind::Gba, SessionOptions::default(), &ckpt)
            .unwrap();
    let auc_one = one.eval_auc(1).unwrap();
    assert!(
        (auc_four - auc_one).abs() < 1e-12,
        "checkpoint not shard-portable: {auc_four} vs {auc_one}"
    );
}
