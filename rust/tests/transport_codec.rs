//! Property tests for the wire codec (seeded, offline — `util::prop`).
//!
//! Pins the three robustness guarantees of the transport frame format:
//! lossless round-trips (including NaN/inf gradients, empty embedding
//! maps and max-value keys), rejection of truncated frames at *every*
//! cut point, and rejection of version/tag mismatches — all without
//! panicking or allocating from untrusted lengths.

use gba::embedding::RowMeta;
use gba::ps::{GradPush, PullReply, WorkItem};
use gba::runtime::HostTensor;
use gba::transport::codec::{
    decode, encode, read_frame, write_frame, CodecError, WireMsg, WIRE_VERSION,
};
use gba::transport::{ShardReply, ShardRequest};
use gba::util::prop::{check, gen};
use gba::util::rng::Pcg64;

/// f32 generator biased toward the codec's hard cases.
fn weird_f32(rng: &mut Pcg64) -> f32 {
    match rng.gen_range(8) {
        0 => f32::NAN,
        1 => f32::INFINITY,
        2 => f32::NEG_INFINITY,
        3 => -0.0,
        4 => f32::MIN_POSITIVE / 2.0, // subnormal
        _ => gen::f32_in(rng, 1e6),
    }
}

/// Key generator biased toward boundary values ("max-length" keys).
fn weird_key(rng: &mut Pcg64) -> u64 {
    match rng.gen_range(6) {
        0 => u64::MAX,
        1 => 0,
        2 => u64::MAX - 1,
        _ => rng.next_u64(),
    }
}

fn random_push(rng: &mut Pcg64) -> GradPush {
    let dense = gen::vec_of(rng, 0, 4, |rng| {
        let rows = gen::usize_in(rng, 0, 3);
        let cols = gen::usize_in(rng, 0, 4);
        HostTensor {
            shape: vec![rows, cols],
            data: (0..rows * cols).map(|_| weird_f32(rng)).collect(),
        }
    });
    // Empty embedding maps and empty per-key gradients must survive.
    let emb = gen::vec_of(rng, 0, 6, |rng| {
        (weird_key(rng), gen::vec_of(rng, 0, 5, weird_f32))
    });
    GradPush {
        worker: gen::usize_in(rng, 0, 1 << 20),
        token: weird_key(rng),
        dense,
        emb,
        n_samples: gen::usize_in(rng, 0, 1 << 16),
        loss: weird_f32(rng),
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn assert_push_eq(a: &GradPush, b: &GradPush) {
    assert_eq!(a.worker, b.worker);
    assert_eq!(a.token, b.token);
    assert_eq!(a.n_samples, b.n_samples);
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    assert_eq!(a.dense.len(), b.dense.len());
    for (x, y) in a.dense.iter().zip(&b.dense) {
        assert_eq!(x.shape, y.shape);
        assert_eq!(bits(&x.data), bits(&y.data));
    }
    assert_eq!(a.emb.len(), b.emb.len());
    for ((ka, ga), (kb, gb)) in a.emb.iter().zip(&b.emb) {
        assert_eq!(ka, kb);
        assert_eq!(bits(ga), bits(gb));
    }
}

#[test]
fn prop_grad_push_roundtrip() {
    check("grad_push_roundtrip", 200, |rng| {
        let g = random_push(rng);
        let body = encode(&WireMsg::Push(g.clone()));
        match decode(&body).expect("well-formed frame must decode") {
            WireMsg::Push(back) => assert_push_eq(&g, &back),
            other => panic!("wrong variant: {other:?}"),
        }
    });
}

#[test]
fn prop_pull_reply_roundtrip() {
    check("pull_reply_roundtrip", 200, |rng| {
        let reply = match rng.gen_range(3) {
            0 => PullReply::Work(WorkItem {
                token: weird_key(rng),
                version: rng.next_u64(),
                day: gen::usize_in(rng, 0, 1 << 20),
                batch_index: gen::usize_in(rng, 0, 1 << 20),
            }),
            1 => PullReply::Wait,
            _ => PullReply::EndOfData,
        };
        let body = encode(&WireMsg::Pull(reply));
        match decode(&body).unwrap() {
            WireMsg::Pull(back) => assert_eq!(back, reply),
            other => panic!("wrong variant: {other:?}"),
        }
    });
}

#[test]
fn prop_shard_rpc_roundtrip() {
    check("shard_rpc_roundtrip", 200, |rng| {
        let msg = match rng.gen_range(5) {
            0 => WireMsg::Req(ShardRequest::Apply {
                opt_step: rng.next_u64(),
                dense: gen::vec_of(rng, 0, 4, |rng| gen::vec_of(rng, 0, 8, weird_f32)),
                emb: gen::vec_of(rng, 0, 5, |rng| {
                    (weird_key(rng), gen::vec_of(rng, 0, 4, weird_f32), rng.next_u32())
                }),
            }),
            1 => WireMsg::Req(ShardRequest::InsertRow {
                key: weird_key(rng),
                vec: gen::vec_of(rng, 0, 6, weird_f32),
                state: gen::vec_of(rng, 0, 12, weird_f32),
                meta: RowMeta { last_update_step: rng.next_u64(), update_count: rng.next_u32() },
            }),
            2 => WireMsg::Req(ShardRequest::InsertRows {
                rows: gen::vec_of(rng, 0, 6, |rng| {
                    (
                        weird_key(rng),
                        gen::vec_of(rng, 0, 4, weird_f32),
                        gen::vec_of(rng, 0, 8, weird_f32),
                        RowMeta {
                            last_update_step: rng.next_u64(),
                            update_count: rng.next_u32(),
                        },
                    )
                }),
            }),
            3 => WireMsg::Reply(ShardReply::RowDump {
                rows: gen::vec_of(rng, 0, 4, |rng| {
                    (
                        weird_key(rng),
                        gen::vec_of(rng, 0, 4, weird_f32),
                        gen::vec_of(rng, 0, 8, weird_f32),
                        RowMeta {
                            last_update_step: rng.next_u64(),
                            update_count: rng.next_u32(),
                        },
                    )
                }),
            }),
            _ => WireMsg::Reply(ShardReply::Rows {
                dim: rng.gen_range(16),
                data: gen::vec_of(rng, 0, 32, weird_f32),
            }),
        };
        let body = encode(&msg);
        let back = decode(&body).expect("well-formed frame must decode");
        // Cheap structural equality: re-encoding must be byte-identical
        // (the codec is deterministic, so this is an iff).
        assert_eq!(encode(&back), body, "decode/encode not a fixed point");
    });
}

#[test]
fn prop_truncated_frames_rejected_never_panic() {
    check("truncation_rejected", 60, |rng| {
        let body = encode(&WireMsg::Push(random_push(rng)));
        // Every prefix must fail cleanly (except the full frame).
        for cut in 0..body.len() {
            match decode(&body[..cut]) {
                Err(_) => {}
                Ok(m) => panic!("decoded a {cut}/{}-byte prefix: {m:?}", body.len()),
            }
        }
        // And so must a frame with random trailing junk.
        let mut padded = body.clone();
        padded.extend((0..gen::usize_in(rng, 1, 8)).map(|_| rng.next_u32() as u8));
        assert!(decode(&padded).is_err(), "trailing junk accepted");
    });
}

#[test]
fn prop_wrong_version_and_tag_rejected() {
    check("version_tag_rejected", 100, |rng| {
        let mut body = encode(&WireMsg::Pull(PullReply::Wait));
        let bad_version = (rng.next_u32() as u8).wrapping_add(WIRE_VERSION + 1);
        body[0] = if bad_version == WIRE_VERSION { WIRE_VERSION + 1 } else { bad_version };
        assert!(matches!(decode(&body), Err(CodecError::BadVersion(_))));

        // The tag sits after the version byte and the u64 trace id.
        let mut body = encode(&WireMsg::Pull(PullReply::Wait));
        body[9] = 7 + (rng.next_u32() % 240) as u8; // valid tags are 1..=6
        assert!(matches!(decode(&body), Err(CodecError::BadTag(_))));
    });
}

#[test]
fn prop_random_bytes_never_panic() {
    check("fuzz_decode", 300, |rng| {
        let junk: Vec<u8> = (0..gen::usize_in(rng, 0, 200)).map(|_| rng.next_u32() as u8).collect();
        let _ = decode(&junk); // must return, not panic or OOM
        let mut r = &junk[..];
        let _ = read_frame(&mut r);
    });
}

#[test]
fn framed_stream_roundtrip_many() {
    let mut rng = Pcg64::new(0xC0DEC, 1);
    let msgs: Vec<WireMsg> = (0..20).map(|_| WireMsg::Push(random_push(&mut rng))).collect();
    let mut buf = Vec::new();
    for m in &msgs {
        write_frame(&mut buf, m).unwrap();
    }
    let mut r = &buf[..];
    for m in &msgs {
        match (read_frame(&mut r).unwrap(), m) {
            (WireMsg::Push(a), WireMsg::Push(b)) => assert_push_eq(b, &a),
            (got, want) => panic!("{got:?} vs {want:?}"),
        }
    }
    assert_eq!(read_frame(&mut r).unwrap_err(), CodecError::Closed);
}
