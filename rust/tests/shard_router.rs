//! Property tests on the consistent-hash shard router: balance within
//! ±15%, stability at a fixed shard count, and the consistent-hashing
//! migration invariant when the plane grows.

use gba::shard::ShardRouter;
use gba::util::prop;
use gba::util::rng::Pcg64;

fn random_keys(rng: &mut Pcg64, n: usize) -> Vec<u64> {
    (0..n).map(|_| rng.next_u64()).collect()
}

#[test]
fn router_balances_keys_within_15_percent() {
    prop::check("router balance", 20, |rng| {
        let n_shards = [2usize, 3, 4, 8, 16][rng.gen_range(5) as usize];
        let keys = random_keys(rng, 40_000);
        let router = ShardRouter::new(n_shards);
        let mut counts = vec![0usize; n_shards];
        for &k in &keys {
            counts[router.shard_of_key(k)] += 1;
        }
        let mean = keys.len() as f64 / n_shards as f64;
        for (s, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - mean).abs() / mean;
            assert!(
                dev <= 0.15,
                "shard {s}/{n_shards} holds {c} keys, mean {mean:.0} (dev {:.1}%)",
                dev * 100.0
            );
        }
    });
}

#[test]
fn keys_never_migrate_at_fixed_shard_count() {
    prop::check("router stability", 20, |rng| {
        let n_shards = 1 + rng.gen_range(16) as usize;
        let keys = random_keys(rng, 5_000);
        let a = ShardRouter::new(n_shards);
        let first: Vec<usize> = keys.iter().map(|&k| a.shard_of_key(k)).collect();
        // Re-querying the same router and querying an independently
        // constructed router with the same n must both agree.
        let b = ShardRouter::new(n_shards);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(a.shard_of_key(k), first[i], "same-router requery moved key {k}");
            assert_eq!(b.shard_of_key(k), first[i], "rebuilt router moved key {k}");
        }
    });
}

#[test]
fn growing_the_plane_only_moves_keys_to_the_new_shard() {
    prop::check("router consistent migration", 15, |rng| {
        let n = 2 + rng.gen_range(7) as usize; // 2..=8
        let keys = random_keys(rng, 20_000);
        let old = ShardRouter::new(n);
        let new = ShardRouter::new(n + 1);
        let mut moved = 0usize;
        for &k in &keys {
            let a = old.shard_of_key(k);
            let b = new.shard_of_key(k);
            if a != b {
                moved += 1;
                // Rendezvous hashing: a key only moves when the *new*
                // shard wins its vote — never between surviving shards.
                assert_eq!(b, n, "key {k} moved {a} -> {b}, not to the new shard {n}");
            }
        }
        // Expected migration fraction is 1/(n+1); allow a wide band.
        let frac = moved as f64 / keys.len() as f64;
        let expect = 1.0 / (n as f64 + 1.0);
        assert!(
            (frac - expect).abs() < 0.05,
            "n {n}->{}: migrated {frac:.3}, expected ~{expect:.3}",
            n + 1
        );
    });
}

#[test]
fn dense_ranges_partition_every_tensor_length() {
    prop::check("router dense ranges", 30, |rng| {
        let n_shards = 1 + rng.gen_range(12) as usize;
        let len = rng.gen_range(100_000) as usize;
        let router = ShardRouter::new(n_shards);
        let mut covered = 0usize;
        for s in 0..n_shards {
            let (lo, hi) = router.dense_range(s, len);
            assert_eq!(lo, covered, "gap/overlap at shard {s}");
            assert!(hi >= lo && hi <= len);
            covered = hi;
        }
        assert_eq!(covered, len, "ranges must tile [0, {len})");
    });
}
