//! Shard-local checkpoint round-trip: save an N-shard PS as per-shard
//! streams, reload at the same and at different shard counts (and a
//! different transport), and assert the restored servers produce
//! *identical pull snapshots* — dense parameter pulls and embedding
//! gathers are byte-equal to the origin server's.

use gba::checkpoint::Checkpoint;
use gba::config::TransportKind;
use gba::coordinator::modes::GbaPolicy;
use gba::embedding::EmbeddingConfig;
use gba::optim::Sgd;
use gba::ps::{GradPush, PullReply};
use gba::runtime::{HostTensor, VariantDims};
use gba::shard::{PsBuild, ShardedPs};

fn dims() -> VariantDims {
    VariantDims { fields: 2, emb_dim: 4, hidden1: 8, hidden2: 4, mlp_in: 12 }
}

fn init_params() -> Vec<HostTensor> {
    dims()
        .param_shapes()
        .into_iter()
        .enumerate()
        .map(|(t, s)| {
            let n: usize = s.iter().product();
            HostTensor {
                shape: s,
                data: (0..n).map(|i| 0.2 + t as f32 * 0.05 + i as f32 * 0.011).collect(),
            }
        })
        .collect()
}

fn build(n_shards: usize, transport: TransportKind) -> ShardedPs {
    PsBuild {
        dims: dims(),
        init_params: init_params(),
        emb_cfg: EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 23, shards: 2 },
        opt_dense: Box::new(Sgd { lr: 0.05 }),
        opt_emb: Box::new(Sgd { lr: 0.05 }),
        policy: Box::new(GbaPolicy::with_iota(2, 3)),
        n_shards,
        transport,
        shard_addrs: Vec::new(),
        connect_deadline: None,
        apply_threads: 1,
    }
    .build()
}

fn keys() -> Vec<u64> {
    (0..40).map(|i| i * 57_881 + 7).collect()
}

fn train(ps: &ShardedPs) {
    let keys = keys();
    ps.set_day(0, 100);
    for step in 0..6u64 {
        for j in 0..2u64 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            let g = 0.1 + step as f32 * 0.02 + j as f32 * 0.005;
            ps.push(GradPush {
                worker: 0,
                token: it.token,
                dense: dims()
                    .param_shapes()
                    .into_iter()
                    .map(|s| {
                        let n: usize = s.iter().product();
                        HostTensor { shape: s, data: vec![g; n] }
                    })
                    .collect(),
                emb: keys[..(10 + step as usize * 2)].iter().map(|&k| (k, vec![g; 4])).collect(),
                n_samples: 8,
                loss: 0.4,
            });
        }
    }
}

/// Restore a portable checkpoint into a PS the way sessions do: dense
/// replace + row-by-row insert (fresh optimizer state — switch
/// semantics).
fn restore(ckpt: &Checkpoint, n_shards: usize, transport: TransportKind) -> ShardedPs {
    let ps = build(n_shards, transport);
    ps.set_dense_params(ckpt.dense.clone());
    for (key, vec, meta) in &ckpt.emb_rows {
        ps.insert_emb_row(*key, vec.clone(), Vec::new(), *meta);
    }
    ps
}

/// The "pull snapshot": everything a worker reads from the PS.
fn pull_snapshot(ps: &ShardedPs) -> (Vec<Vec<u32>>, Vec<u32>) {
    let dense: Vec<Vec<u32>> = ps
        .dense_params()
        .into_iter()
        .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
        .collect();
    let keys = keys();
    let gathered = ps.gather(&keys, 8, 5);
    assert_eq!(gathered.shape, vec![8, 5, 4]);
    (dense, gathered.data.iter().map(|x| x.to_bits()).collect())
}

#[test]
fn sharded_save_reload_same_and_different_shard_counts() {
    let origin = build(3, TransportKind::InProc);
    train(&origin);
    assert!(origin.quiescent());
    let want = pull_snapshot(&origin);

    let dir = std::env::temp_dir().join("gba_shard_ckpt_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    Checkpoint::save_sharded(&origin, &dir).unwrap();
    // One stream per shard plus the manifest, nothing else.
    assert!(dir.join("manifest.json").is_file());
    for s in 0..3 {
        assert!(dir.join(format!("shard-{s:03}.bin")).is_file(), "missing stream {s}");
    }
    let ckpt = Checkpoint::load_sharded(&dir).unwrap();
    assert_eq!(ckpt.global_step, origin.global_step());
    assert_eq!(ckpt.emb_rows.len(), origin.emb_len());

    // Same shard count.
    let same = restore(&ckpt, 3, TransportKind::InProc);
    assert_eq!(pull_snapshot(&same), want, "3-shard restore diverged");
    // Different shard counts: the portable form is shard-layout-free.
    for n in [1usize, 2, 5] {
        let other = restore(&ckpt, n, TransportKind::InProc);
        assert_eq!(pull_snapshot(&other), want, "{n}-shard restore diverged");
    }
    // And across the wire.
    let socket = restore(&ckpt, 2, TransportKind::Socket);
    assert_eq!(pull_snapshot(&socket), want, "socket restore diverged");
}

#[test]
fn sharded_save_equals_portable_save() {
    let origin = build(4, TransportKind::InProc);
    train(&origin);
    let dir = std::env::temp_dir().join("gba_shard_ckpt_vs_portable");
    let _ = std::fs::remove_dir_all(&dir);
    Checkpoint::save_sharded(&origin, &dir).unwrap();
    let sharded = Checkpoint::load_sharded(&dir).unwrap();
    let portable = Checkpoint::from_ps(origin.dims, &origin);
    assert_eq!(sharded.dense, portable.dense);
    assert_eq!(sharded.global_step, portable.global_step);
    assert_eq!(sharded.emb_rows.len(), portable.emb_rows.len());
    for (a, b) in sharded.emb_rows.iter().zip(&portable.emb_rows) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2.last_update_step, b.2.last_update_step);
        assert_eq!(a.2.update_count, b.2.update_count);
    }
}
