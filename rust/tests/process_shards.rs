//! The multi-process PS plane, end to end (ISSUE 3 acceptance): real
//! `gba-train shard-server` child processes serve the shards over TCP
//! while this process runs the front.
//!
//! Three pins:
//!
//! * **Bit-identity** — a deterministic single-threaded GBA epoch driven
//!   against two shard-server *processes* produces bit-for-bit the same
//!   dense parameters, embedding rows and loss curve as the same epoch
//!   against in-process shards. (The codec ships `f32`s as raw bits and
//!   both sides derive the same spec from the same config file.)
//! * **Reconnect-and-replay** — killing one child mid-epoch (SIGKILL, a
//!   real process death) and starting a replacement on the same address
//!   lets the supervisor reconnect, install the shard-local checkpoint
//!   over the wire and replay its journal: the run completes
//!   bit-identical to a no-failure run, with exactly one recovery.
//! * **A real training epoch** — `TrainSession` with `transport =
//!   "remote"` trains a day across ≥ 2 OS processes and evaluates sanely.
//!
//! Child stderr goes to `$CARGO_TARGET_TMPDIR/process-shards-logs/` so a
//! CI failure can upload what the shard servers saw.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use gba::config::{ExperimentConfig, ModeKind, TransportKind};
use gba::coordinator::modes::make_policy;
use gba::ps::{GradPush, PullReply};
use gba::runtime::HostTensor;
use gba::shard::{PsBuild, ShardedPs};
use gba::worker::session::{dims_of, shard_server_spec, SessionOptions, TrainSession};

const BIN: &str = env!("CARGO_BIN_EXE_gba-train");
const N_SHARDS: usize = 2;

const CONFIG: &str = r#"
name = "process-shards-test"
seed = 21

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1

[data]
days_base = 1
days_eval = 1
samples_per_day = 4096
teacher_seed = 3
label_noise = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 1024

[mode.sync]
workers = 2
local_batch = 32

[mode.gba]
workers = 4
local_batch = 16
iota = 3

[ps]
n_shards = 2
"#;

fn log_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_TARGET_TMPDIR")).join("process-shards-logs");
    std::fs::create_dir_all(&dir).expect("creating shard-server log dir");
    dir
}

fn write_config(tag: &str) -> PathBuf {
    let path = log_dir().join(format!("{tag}.toml"));
    std::fs::write(&path, CONFIG).expect("writing test config");
    path
}

/// One shard-server child. Killed (and reaped) on drop so a panicking
/// test never leaks processes.
struct ShardProc {
    child: Child,
    addr: String,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `gba-train shard-server` and block until it announces its bound
/// address on stdout (the readiness protocol the CLI guarantees).
fn spawn_shard(config: &Path, shard: usize, listen: &str, log_tag: &str) -> ShardProc {
    let log = std::fs::File::create(log_dir().join(format!("{log_tag}-shard{shard}.log")))
        .expect("creating shard-server log file");
    let mut child = Command::new(BIN)
        .args([
            "shard-server",
            "--config",
            config.to_str().unwrap(),
            "--shard-id",
            &shard.to_string(),
            "--listen",
            listen,
            "--mode",
            "gba",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::from(log))
        .spawn()
        .expect("spawning shard-server child");
    let mut line = String::new();
    BufReader::new(child.stdout.take().expect("child stdout"))
        .read_line(&mut line)
        .expect("reading shard-server banner");
    let addr = line
        .strip_prefix("shard-server listening on ")
        .unwrap_or_else(|| panic!("unexpected shard-server banner: {line:?}"))
        .split_whitespace()
        .next()
        .expect("address token")
        .to_string();
    ShardProc { child, addr }
}

fn spawn_plane(config: &Path, log_tag: &str) -> Vec<ShardProc> {
    (0..N_SHARDS).map(|s| spawn_shard(config, s, "127.0.0.1:0", log_tag)).collect()
}

/// Spawn a shard-server with `--obs-listen`: the first stdout line is
/// still the address banner (that contract is pinned by every other
/// test here), the second announces the obs metrics address.
fn spawn_shard_with_obs(config: &Path, shard: usize, log_tag: &str) -> (ShardProc, String) {
    let log = std::fs::File::create(log_dir().join(format!("{log_tag}-shard{shard}.log")))
        .expect("creating shard-server log file");
    let mut child = Command::new(BIN)
        .args([
            "shard-server",
            "--config",
            config.to_str().unwrap(),
            "--shard-id",
            &shard.to_string(),
            "--listen",
            "127.0.0.1:0",
            "--mode",
            "gba",
            "--obs-listen",
            "127.0.0.1:0",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::from(log))
        .spawn()
        .expect("spawning shard-server child");
    let mut reader = BufReader::new(child.stdout.take().expect("child stdout"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("reading shard-server banner");
    let addr = line
        .strip_prefix("shard-server listening on ")
        .unwrap_or_else(|| panic!("unexpected shard-server banner: {line:?}"))
        .split_whitespace()
        .next()
        .expect("address token")
        .to_string();
    let mut obs_line = String::new();
    reader.read_line(&mut obs_line).expect("reading obs announcement");
    let obs_addr = obs_line
        .strip_prefix("obs metrics listening on ")
        .unwrap_or_else(|| panic!("unexpected obs announcement: {obs_line:?}"))
        .trim()
        .to_string();
    (ShardProc { child, addr }, obs_addr)
}

/// Raw HTTP/1.0 GET against a child process's obs listener.
fn scrape_metrics(addr: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("connecting to obs listener");
    s.write_all(b"GET /metrics HTTP/1.0\r\nHost: test\r\n\r\n").expect("sending scrape");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("reading exposition");
    resp
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig::from_toml(CONFIG).expect("test config parses")
}

fn remote_cfg(addrs: Vec<String>) -> ExperimentConfig {
    let mut cfg = base_cfg();
    cfg.ps.transport = TransportKind::Remote;
    cfg.ps.shard_addrs = addrs;
    cfg
}

/// Build a front exactly the way a session would, but driveable
/// deterministically from one thread. The spec helper is the same one
/// `shard-server` uses, so front and children agree on ranges,
/// embedding seed and optimizers by construction.
fn build_front(cfg: &ExperimentConfig) -> ShardedPs {
    let (spec, init) = shard_server_spec(cfg, ModeKind::Gba, 0);
    let mode = cfg.mode(ModeKind::Gba);
    PsBuild {
        dims: dims_of(cfg),
        init_params: init,
        emb_cfg: spec.emb_cfg.clone(),
        opt_dense: spec.opt_dense.boxed_clone(),
        opt_emb: spec.opt_emb.boxed_clone(),
        policy: make_policy(ModeKind::Gba, &mode, cfg.gba_m_effective()),
        n_shards: cfg.ps.n_shards,
        transport: cfg.ps.transport,
        shard_addrs: cfg.ps.shard_addrs.clone(),
        connect_deadline: None,
        apply_threads: 1,
    }
    .build()
}

fn grad(cfg: &ExperimentConfig, token: u64, keys: &[u64], g: f32) -> GradPush {
    GradPush {
        worker: 0,
        token,
        dense: dims_of(cfg)
            .param_shapes()
            .into_iter()
            .map(|s| {
                let n: usize = s.iter().product();
                HostTensor { shape: s, data: (0..n).map(|i| g + i as f32 * 1e-3).collect() }
            })
            .collect(),
        emb: keys.iter().map(|&k| (k, vec![g; 4])).collect(),
        n_samples: 16,
        loss: 0.5 + g * 0.1,
    }
}

struct EpochResult {
    dense_bits: Vec<Vec<u32>>,
    rows_bits: Vec<Vec<u32>>,
    loss_curve: Vec<(u64, f32)>,
    global_steps: u64,
    lost_events: u64,
}

/// Drive 6 GBA global batches (M = 4) plus a partial flush from a single
/// thread — fully deterministic. `after_push(step, j)` runs after each
/// push; the fault tests use it to kill/replace a child at an exact
/// point in program order.
fn run_epoch(cfg: &ExperimentConfig, mut after_push: impl FnMut(u64, u64)) -> EpochResult {
    let m = cfg.gba_m_effective() as u64;
    assert_eq!(m, 4);
    let keys: Vec<u64> = (0..24).map(|i| i * 104_729 + 11).collect();
    let ps = build_front(cfg);
    ps.set_day(0, 1000);
    for step in 0..6u64 {
        for j in 0..m {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            let g = 0.2 + step as f32 * 0.03 + j as f32 * 0.01;
            ps.push(grad(cfg, it.token, &keys[..(6 + step as usize)], g));
            after_push(step, j);
        }
    }
    let it = match ps.pull(0) {
        PullReply::Work(it) => it,
        other => panic!("{other:?}"),
    };
    ps.push(grad(cfg, it.token, &keys[..4], 0.9));
    assert!(ps.flush_partial());
    assert!(ps.quiescent());
    EpochResult {
        dense_bits: ps
            .dense_params()
            .into_iter()
            .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
            .collect(),
        rows_bits: keys
            .iter()
            .map(|&k| ps.emb_row(k).iter().map(|x| x.to_bits()).collect())
            .collect(),
        loss_curve: ps.loss_curve(),
        global_steps: ps.counters().global_steps,
        lost_events: ps.lost_shard_events(),
    }
}

fn assert_bit_identical(a: &EpochResult, b: &EpochResult) {
    assert_eq!(a.global_steps, b.global_steps);
    assert_eq!(a.loss_curve, b.loss_curve, "loss curves diverged");
    assert_eq!(a.dense_bits, b.dense_bits, "dense parameters diverged");
    assert_eq!(a.rows_bits, b.rows_bits, "embedding rows diverged");
}

/// Acceptance core: shards in real child processes are bit-identical to
/// in-process shards on an identical pull/push schedule.
#[test]
fn remote_processes_bit_identical_to_inproc() {
    let inproc = run_epoch(&base_cfg(), |_, _| {});
    assert_eq!(inproc.lost_events, 0);

    let config = write_config("bitident");
    let plane = spawn_plane(&config, "bitident");
    let addrs: Vec<String> = plane.iter().map(|p| p.addr.clone()).collect();
    let remote = run_epoch(&remote_cfg(addrs), |_, _| {});
    assert_eq!(remote.lost_events, 0, "clean remote run must not recover");
    assert_bit_identical(&remote, &inproc);
}

/// Kill one shard-server with SIGKILL mid-epoch (mid-global-batch), put
/// a replacement on the same address, and finish: exactly one
/// reconnect-and-replay recovery, results bit-identical to both the
/// clean remote run and the in-process run.
#[test]
fn killed_shard_server_process_recovers_bit_identically() {
    let inproc = run_epoch(&base_cfg(), |_, _| {});

    let config = write_config("killrestart");
    let mut plane = spawn_plane(&config, "killrestart");
    let addrs: Vec<String> = plane.iter().map(|p| p.addr.clone()).collect();
    let victim_addr = addrs[0].clone();
    let cfg = remote_cfg(addrs);
    let config2 = config.clone();
    let mut killed = false;
    let faulty = run_epoch(&cfg, |step, j| {
        // After the second push of global batch 3: the flush that
        // completes this batch is the one that finds the corpse.
        if step == 3 && j == 1 && !killed {
            killed = true;
            plane[0].child.kill().expect("killing shard-server child");
            plane[0].child.wait().expect("reaping shard-server child");
            // The replacement binds the same address the front dials.
            plane[0] = spawn_shard(&config2, 0, &victim_addr, "killrestart-respawn");
        }
    });
    assert!(killed, "fault injection never ran");
    assert_eq!(faulty.lost_events, 1, "exactly one lost-shard recovery");
    assert_bit_identical(&faulty, &inproc);
}

/// ROADMAP follow-up (v): a shard-server that never answers within the
/// (configurable) connect deadline surfaces as `Err` through
/// `TrainSession::new` — with a message naming the shard — instead of
/// panicking after the redial window. `gba-train train` turns that into
/// a clean nonzero exit.
#[test]
fn unreachable_shard_server_is_an_err_not_a_panic() {
    // A dynamic-range port with nothing bound: bind, read, drop.
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut cfg = remote_cfg(vec![addr.clone(), addr.clone()]);
    cfg.ps.connect_deadline_ms = 300;
    let t0 = Instant::now();
    let err = match TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default()) {
        Err(e) => e,
        Ok(_) => panic!("session built against a never-bound shard address"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 0"), "error does not name the shard: {msg}");
    assert!(msg.contains(&addr), "error does not name the address: {msg}");
    // The short deadline bounds the build; far under the default 20 s.
    assert!(t0.elapsed() < Duration::from_secs(10), "took {:?}", t0.elapsed());
}

/// ISSUE 6 acceptance: with `--obs-listen` set, a shard-server child
/// serves a Prometheus exposition whose per-RPC counters are consistent
/// with the run the front just drove — every flush the front counted as
/// a global step sent this shard exactly one Apply RPC, and the
/// shard-side apply-latency histogram saw exactly that many samples.
#[test]
fn shard_server_metrics_exposition_matches_the_run() {
    let config = write_config("obs-scrape");
    let (observed, obs_addr) = spawn_shard_with_obs(&config, 0, "obs-scrape");
    let plain = spawn_shard(&config, 1, "127.0.0.1:0", "obs-scrape");
    let cfg = remote_cfg(vec![observed.addr.clone(), plain.addr.clone()]);
    let result = run_epoch(&cfg, |_, _| {});
    assert_eq!(result.lost_events, 0, "clean run must not recover");

    let resp = scrape_metrics(&obs_addr);
    assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
    let want_apply =
        format!("gba_shard_requests_total{{rpc=\"apply\"}} {}", result.global_steps);
    assert!(resp.contains(&want_apply), "expected {want_apply:?} in exposition:\n{resp}");
    let want_hist =
        format!("gba_shard_apply_seconds_count{{shard=\"0\"}} {}", result.global_steps);
    assert!(resp.contains(&want_hist), "expected {want_hist:?} in exposition:\n{resp}");
    // The listener is a live view, not a one-shot dump: a second scrape
    // still answers (and the counters have not gone backwards).
    let again = scrape_metrics(&obs_addr);
    assert!(again.contains(&want_apply), "second scrape lost the counters:\n{again}");
}

/// A real multi-worker training day over ≥ 2 OS processes: the session
/// layer only changed its config, and the model still learns.
#[test]
fn session_trains_an_epoch_across_real_processes() {
    let config = write_config("session");
    let plane = spawn_plane(&config, "session");
    let addrs: Vec<String> = plane.iter().map(|p| p.addr.clone()).collect();
    let cfg = remote_cfg(addrs);
    let session = TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default())
        .expect("building remote session");
    assert_eq!(session.ps().transport(), TransportKind::Remote);
    assert_eq!(session.ps().n_shards(), N_SHARDS);
    let before = session.eval_auc(1).expect("eval before");
    let stats = session.train_day(0).expect("training a day across processes");
    assert!(stats.counters.global_steps > 0);
    let after = session.eval_auc(1).expect("eval after");
    assert!(after > before, "auc did not improve: {before} -> {after}");
    assert!(after > 0.55, "auc after one remote day = {after}");
    assert_eq!(session.ps().lost_shard_events(), 0);
}
