//! Integration: load the AOT artifacts via PJRT and execute train_step /
//! predict with concrete inputs. Requires `make artifacts` (tiny variant).

use gba::runtime::{EnginePool, HostTensor, Manifest};
use gba::util::rng::Pcg64;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn rand_tensor(rng: &mut Pcg64, shape: Vec<usize>, scale: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect();
    HostTensor::new(shape, data).unwrap()
}

#[test]
fn train_step_and_predict_roundtrip() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let dims = manifest.dims("tiny").unwrap();
    let batch = manifest.batches("tiny").unwrap()[0];

    let pool = EnginePool::start(&manifest, "tiny", 2).unwrap();
    let h = pool.handle();

    let mut rng = Pcg64::seeded(7);
    let emb = rand_tensor(&mut rng, vec![batch, dims.fields, dims.emb_dim], 0.3);
    let params: Vec<HostTensor> = dims
        .param_shapes()
        .into_iter()
        .map(|s| rand_tensor(&mut rng, s, 0.2))
        .collect();
    let labels: Vec<f32> = (0..batch).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();

    let out = h.train_step(batch, emb.clone(), params.clone(), labels.clone()).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0, "loss={}", out.loss);
    assert_eq!(out.logits.len(), batch);
    assert_eq!(out.d_emb.shape, vec![batch, dims.fields, dims.emb_dim]);
    assert_eq!(out.d_dense.len(), 6);
    for (g, s) in out.d_dense.iter().zip(dims.param_shapes()) {
        assert_eq!(g.shape, s);
    }

    // predict logits must match train_step logits on identical inputs.
    let logits = h.predict(batch, emb.clone(), params.clone()).unwrap();
    for (a, b) in logits.iter().zip(&out.logits) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }

    // Executing from several caller threads concurrently must work.
    let mut joins = Vec::new();
    for _ in 0..4 {
        let h = h.clone();
        let emb = emb.clone();
        let params = params.clone();
        let labels = labels.clone();
        joins.push(std::thread::spawn(move || {
            h.train_step(batch, emb, params, labels).unwrap().loss
        }));
    }
    for j in joins {
        let loss = j.join().unwrap();
        assert!((loss - out.loss).abs() < 1e-6);
    }
    pool.shutdown();
}

#[test]
fn gradient_step_reduces_loss_via_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let dims = manifest.dims("tiny").unwrap();
    let batch = manifest.batches("tiny").unwrap()[0];
    let pool = EnginePool::start(&manifest, "tiny", 1).unwrap();
    let h = pool.handle();

    let mut rng = Pcg64::seeded(11);
    let emb = rand_tensor(&mut rng, vec![batch, dims.fields, dims.emb_dim], 0.3);
    let mut params: Vec<HostTensor> = dims
        .param_shapes()
        .into_iter()
        .map(|s| rand_tensor(&mut rng, s, 0.2))
        .collect();
    let labels: Vec<f32> = (0..batch).map(|i| (i % 2) as f32).collect();

    let first = h.train_step(batch, emb.clone(), params.clone(), labels.clone()).unwrap();
    let mut last = first.loss;
    for _ in 0..10 {
        let out = h.train_step(batch, emb.clone(), params.clone(), labels.clone()).unwrap();
        for (p, g) in params.iter_mut().zip(&out.d_dense) {
            p.axpy(-0.5, g);
        }
        last = out.loss;
    }
    assert!(last < first.loss, "no improvement: {} -> {last}", first.loss);
    pool.shutdown();
}
