//! In-place mode switching over real processes (ISSUE 5 acceptance):
//! the session advances its mode epoch while remote `gba-train
//! shard-server` and `gba-train worker` children keep running — no
//! teardown, no restart, the paper's headline switch on the one
//! topology where it matters.
//!
//! Three pins:
//!
//! * **Bit-identity across the switch** — a sync → gba → sync day
//!   sequence trained by one real worker process against two real
//!   shard-server processes is bit-for-bit identical to the same
//!   sequence with in-thread workers and in-process shards. The switch
//!   re-handshake and the `SwapPolicy`/`swap_policy` plumbing must not
//!   change a single bit of what is computed.
//! * **Re-handshake failure is loud** — a worker SIGKILLed while parked
//!   between days fails the *switch* (and with it the next day) with a
//!   named error instead of training a half-switched fleet; the control
//!   plane holds no leaked claims (the epoch boundary is drained).
//! * **Adaptive switching, live** — a 2-day `[switch] policy =
//!   "adaptive"` session over a real shard-server and four real worker
//!   processes (one a deterministic straggler) records a SwitchEvent
//!   and finishes the second day in GBA.
//!
//! Child stderr goes to `$CARGO_TARGET_TMPDIR/process-switch-logs/` so
//! a CI failure can upload what the children saw.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use gba::config::{ExperimentConfig, ModeKind, SwitchPolicyKind, TransportKind, WorkerPlane};
use gba::worker::session::{SessionOptions, TrainSession};

const BIN: &str = env!("CARGO_BIN_EXE_gba-train");

/// One worker for the bit-identity arm (a fully ordered schedule, as in
/// `process_workers.rs`): sync trains 32-batches, gba 16-batches with
/// M = 32/16 = 2, so every mode's shape differs and the re-handshake
/// carries real information.
const CONFIG_SWITCH: &str = r#"
name = "process-switch-test"
seed = 51

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1

[data]
days_base = 3
days_eval = 1
samples_per_day = 2048
teacher_seed = 3
label_noise = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 1024

[mode.sync]
workers = 1
local_batch = 32

[mode.gba]
workers = 1
local_batch = 16
iota = 3

[ps]
n_shards = 2
"#;

/// Two workers for the loud-failure pin; four for the adaptive storm.
const CONFIG_FLEET: &str = r#"
name = "process-switch-fleet"
seed = 52

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1

[data]
days_base = 2
days_eval = 1
samples_per_day = 1024
teacher_seed = 3
label_noise = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 1024

[mode.sync]
workers = 4
local_batch = 32

[mode.gba]
workers = 4
local_batch = 16
iota = 3

[switch]
policy = "adaptive"
"#;

fn log_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_TARGET_TMPDIR")).join("process-switch-logs");
    std::fs::create_dir_all(&dir).expect("creating switch log dir");
    dir
}

fn write_config(tag: &str, toml: &str) -> PathBuf {
    let path = log_dir().join(format!("{tag}.toml"));
    std::fs::write(&path, toml).expect("writing test config");
    path
}

/// A child process killed (and reaped) on drop so a panicking test
/// never leaks processes.
struct Proc {
    child: Child,
    addr: String,
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn a shard-server child and block until it announces its bound
/// address. Launched with `--mode sync` — sync and gba share the
/// optimizer pair (Table 5.1), so the live switch never has to restart
/// the server.
fn spawn_shard(config: &Path, shard: usize, log_tag: &str) -> Proc {
    let log = std::fs::File::create(log_dir().join(format!("{log_tag}-shard{shard}.log")))
        .expect("creating shard-server log file");
    let mut child = Command::new(BIN)
        .args([
            "shard-server",
            "--config",
            config.to_str().unwrap(),
            "--shard-id",
            &shard.to_string(),
            "--listen",
            "127.0.0.1:0",
            "--mode",
            "sync",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::from(log))
        .spawn()
        .expect("spawning shard-server child");
    let mut line = String::new();
    BufReader::new(child.stdout.take().expect("child stdout"))
        .read_line(&mut line)
        .expect("reading shard-server banner");
    let addr = line
        .strip_prefix("shard-server listening on ")
        .unwrap_or_else(|| panic!("unexpected shard-server banner: {line:?}"))
        .split_whitespace()
        .next()
        .expect("address token")
        .to_string();
    Proc { child, addr }
}

fn spawn_worker(config: &Path, worker_id: usize, addr: &str, log_tag: &str, extra: &[&str]) -> Proc {
    let log = std::fs::File::create(log_dir().join(format!("{log_tag}-worker{worker_id}.log")))
        .expect("creating worker log file");
    let child = Command::new(BIN)
        .args([
            "worker",
            "--config",
            config.to_str().unwrap(),
            "--connect",
            addr,
            "--worker-id",
            &worker_id.to_string(),
            "--mode",
            "sync",
        ])
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::from(log))
        .spawn()
        .expect("spawning worker child");
    Proc { child, addr: addr.to_string() }
}

/// Raw-bit fingerprint of the session's trained state plus counters.
#[derive(PartialEq, Debug)]
struct Fingerprint {
    dense_bits: Vec<Vec<u32>>,
    rows: Vec<(u64, Vec<u32>, u64, u32)>,
    applied: u64,
    dropped: u64,
    steps: u64,
}

fn fingerprint(session: &TrainSession, applied: u64, dropped: u64, steps: u64) -> Fingerprint {
    let ckpt = session.checkpoint();
    Fingerprint {
        dense_bits: ckpt
            .dense
            .iter()
            .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
            .collect(),
        rows: ckpt
            .emb_rows
            .iter()
            .map(|(k, v, m)| {
                (*k, v.iter().map(|x| x.to_bits()).collect(), m.last_update_step, m.update_count)
            })
            .collect(),
        applied,
        dropped,
        steps,
    }
}

/// Run the day sequence sync → (switch) gba → (switch) sync on an
/// existing session, returning the accumulated counters.
fn run_switch_sequence(session: &mut TrainSession) -> (u64, u64, u64) {
    let (mut applied, mut dropped, mut steps) = (0u64, 0u64, 0u64);
    for (day, switch_to) in [(0usize, None), (1, Some(ModeKind::Gba)), (2, Some(ModeKind::Sync))] {
        if let Some(to) = switch_to {
            session.switch_mode(to).expect("in-place switch");
        }
        let stats = session.train_day(day).expect("training day");
        applied += stats.counters.applied_gradients;
        dropped += stats.counters.dropped_batches;
        steps += stats.counters.global_steps;
        assert_eq!(stats.failures, 0, "clean day {day}");
    }
    (applied, dropped, steps)
}

/// Acceptance core: a mid-run sync ↔ gba switch with remote workers and
/// remote shards is bit-identical to the equivalent in-process run —
/// `switch_mode` neither rebuilds the session nor rejects `[cluster]
/// workers = "remote"` anymore.
#[test]
fn switch_over_real_processes_bit_identical_to_inproc() {
    // In-process reference.
    let cfg = ExperimentConfig::from_toml(CONFIG_SWITCH).unwrap();
    let mut reference = TrainSession::new(cfg, ModeKind::Sync, SessionOptions::default()).unwrap();
    let (applied, dropped, steps) = run_switch_sequence(&mut reference);
    let want = fingerprint(&reference, applied, dropped, steps);
    assert_eq!(reference.switch_trace().events.len(), 2, "two switch events recorded");

    // Real processes: two shard servers + one worker, all children.
    let config = write_config("bitident", CONFIG_SWITCH);
    let shards: Vec<Proc> = (0..2).map(|s| spawn_shard(&config, s, "bitident")).collect();
    let mut cfg = ExperimentConfig::from_toml(CONFIG_SWITCH).unwrap();
    cfg.ps.transport = TransportKind::Remote;
    cfg.ps.shard_addrs = shards.iter().map(|p| p.addr.clone()).collect();
    cfg.cluster.workers = WorkerPlane::Remote;
    cfg.validate().unwrap();
    let mut session = TrainSession::new(cfg, ModeKind::Sync, SessionOptions::default()).unwrap();
    let front_addr = session.worker_addr().expect("remote plane binds at build");
    let mut w0 = spawn_worker(&config, 0, &front_addr, "bitident", &[]);
    let (applied, dropped, steps) = run_switch_sequence(&mut session);
    assert!(session.ps().quiescent());
    let got = fingerprint(&session, applied, dropped, steps);

    // Clean end: the worker survived two live switches and exits 0 on
    // the SessionOver farewell.
    session.shutdown_workers();
    drop(session);
    let status = w0.child.wait().expect("waiting for the worker child");
    assert!(status.success(), "worker did not exit cleanly after the switches: {status:?}");

    assert_eq!(got, want, "process planes diverged from in-process across the switch");
}

/// A worker SIGKILLed between days dies with its `BeginDay` pending;
/// the next switch's re-handshake finds the corpse and fails the day
/// loudly — no half-switched fleet — with conservation intact (the
/// boundary holds no claims).
#[test]
fn worker_killed_at_rehandshake_fails_the_switch_loudly() {
    let config = write_config("killswitch", CONFIG_FLEET);
    // Manual policy for this arm: the test drives the switch itself.
    let mut cfg = ExperimentConfig::from_toml(CONFIG_FLEET).unwrap();
    cfg.switch.policy = SwitchPolicyKind::Manual;
    cfg.cluster.workers = WorkerPlane::Remote;
    let mut session = TrainSession::new(cfg, ModeKind::Sync, SessionOptions::default()).unwrap();
    let addr = session.worker_addr().unwrap();
    let mut workers: Vec<Proc> =
        (0..4).map(|w| spawn_worker(&config, w, &addr, "killswitch", &[])).collect();

    session.train_day(0).expect("clean first day");
    assert!(session.ps().quiescent(), "epoch boundary must hold no claims");

    // The victim is parked in BeginDay; SIGKILL it and switch.
    workers[3].child.kill().expect("killing worker child");
    workers[3].child.wait().expect("reaping worker child");
    let err = match session.switch_mode(ModeKind::Gba) {
        Err(e) => e,
        Ok(()) => panic!("switch succeeded over a dead worker"),
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("re-handshake") && msg.contains("worker 3"),
        "unhelpful switch failure: {msg}"
    );
    // Conservation intact: nothing was issued for the aborted epoch.
    assert!(session.ps().quiescent(), "claims leaked across the failed switch");
}

/// The live adaptive controller over real processes: day 0 (sync) sees
/// one deterministic straggler among four workers, the switch plane
/// proposes GBA, the worker fleet re-handshakes, and day 1 trains in
/// GBA — at least one SwitchEvent recorded, exactly as the acceptance
/// criteria demand.
#[test]
fn adaptive_policy_switches_on_straggler_storm_over_processes() {
    let config = write_config("adaptive", CONFIG_FLEET);
    let shard = spawn_shard(&config, 0, "adaptive");
    let mut cfg = ExperimentConfig::from_toml(CONFIG_FLEET).unwrap();
    cfg.ps.n_shards = 1;
    cfg.ps.transport = TransportKind::Remote;
    cfg.ps.shard_addrs = vec![shard.addr.clone()];
    cfg.cluster.workers = WorkerPlane::Remote;
    cfg.validate().unwrap();
    assert_eq!(cfg.switch.policy, SwitchPolicyKind::Adaptive, "config drives the policy");
    let mut session = TrainSession::new(cfg, ModeKind::Sync, SessionOptions::default()).unwrap();
    let addr = session.worker_addr().unwrap();
    let mut workers = Vec::new();
    for w in 0..4 {
        // Worker 3 is a deterministic straggler: 25 ms per batch vs the
        // sub-millisecond tiny-model compute of the other three.
        let extra: &[&str] = if w == 3 { &["--batch-sleep-ms", "25"] } else { &[] };
        workers.push(spawn_worker(&config, w, &addr, "adaptive", extra));
    }

    let stats0 = session.train_day(0).expect("straggler-storm day");
    assert!(
        stats0.straggler_signal() > 0.6,
        "storm not visible in telemetry: signal {:.3} (p95 {:.5}s, med {:.5}s)",
        stats0.straggler_signal(),
        stats0.batch_latency_p95,
        stats0.batch_latency_med
    );
    let switched = session.observe_day(&stats0).expect("adaptive switch");
    assert_eq!(switched, Some(ModeKind::Gba), "controller must fire on the storm");
    assert_eq!(session.kind, ModeKind::Gba);

    let stats1 = session.train_day(1).expect("GBA day after the live switch");
    assert!(stats1.counters.global_steps > 0);
    assert!(session.ps().quiescent());

    let trace = session.switch_trace();
    assert_eq!(trace.events.len(), 1, "exactly one SwitchEvent in the storm scenario");
    assert_eq!(
        (trace.events[0].day, trace.events[0].from, trace.events[0].to),
        (1, ModeKind::Sync, ModeKind::Gba)
    );

    // Clean shutdown: all four workers survived the switch and exit 0.
    session.shutdown_workers();
    drop(session);
    for (w, mut proc) in workers.into_iter().enumerate() {
        let status = proc.child.wait().expect("waiting for worker child");
        assert!(status.success(), "worker {w} did not exit cleanly: {status:?}");
    }
}
