//! The remote worker plane, end to end (ISSUE 4 acceptance): real
//! `gba-train worker` child processes drive Algorithm 1 over the wire
//! against a front running in this process.
//!
//! Four pins:
//!
//! * **Bit-identity** — a full training day with `[cluster] workers =
//!   "remote"` (one real worker child, so the pull/push schedule is
//!   fully ordered) produces bit-for-bit the same dense parameters,
//!   embedding rows and counters as the identical config with in-thread
//!   workers. There is exactly one `run_worker`, generic over
//!   `PsClient`; the transports must not change a single bit.
//! * **Fleet scale** — 128 workers, every connection multiplexed onto
//!   the front's ONE event-loop thread, train a sync day bit-identical
//!   to 128 in-thread workers. Sync's cohort barrier plus the control
//!   plane's canonical (token, batch) flush order make the day
//!   schedule-independent, so this pin holds even though 128 racing
//!   connections admit pushes in arbitrary order.
//! * **Worker-process failure** — SIGKILL one of four worker children
//!   mid-day: the front's `worker_reset` path reclaims the in-flight
//!   claim, the day completes on the survivors, and conservation holds
//!   (`applied + dropped + reclaimed == batches`), mirroring
//!   `shard_failure.rs` on the worker side.
//! * **Operator contract** — a worker launched with the wrong `--mode`
//!   (different local batch) is rejected at the `Hello` handshake and
//!   fails the day loudly instead of training a diverging model.
//!
//! Child stderr goes to `$CARGO_TARGET_TMPDIR/process-workers-logs/` so
//! a CI failure can upload what the workers saw.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use gba::config::{ExperimentConfig, ModeKind, WorkerPlane};
use gba::worker::remote::{run_worker_process, WorkerProcOptions};
use gba::worker::session::{SessionOptions, TrainSession};

const BIN: &str = env!("CARGO_BIN_EXE_gba-train");

/// One in-thread-deterministic worker (the bit-identity pin needs a
/// fully ordered schedule; multi-worker interleaving is load-dependent
/// in *both* planes, so determinism — not the wire — is what one worker
/// buys). M = G_sync / B_gba = 64/16 = 4.
const CONFIG_1W: &str = r#"
name = "process-workers-1w"
seed = 33

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1

[data]
days_base = 1
days_eval = 1
samples_per_day = 2048
teacher_seed = 3
label_noise = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 1024

[mode.sync]
workers = 2
local_batch = 32

[mode.gba]
workers = 1
local_batch = 16
iota = 3

[cluster]
workers = "remote"
worker_listen = "127.0.0.1:0"
"#;

/// Four workers and a long day (1024 batches) so a SIGKILL lands
/// mid-day with margin; children run with --batch-sleep-ms to stretch
/// compute deterministically.
const CONFIG_4W: &str = r#"
name = "process-workers-4w"
seed = 34

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1

[data]
days_base = 1
days_eval = 1
samples_per_day = 16384
teacher_seed = 3
label_noise = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 1024

[mode.sync]
workers = 4
local_batch = 32

[mode.gba]
workers = 4
local_batch = 16
iota = 3

[cluster]
workers = "remote"
worker_listen = "127.0.0.1:0"
"#;

/// 128 sync workers at a tiny local batch: 256 batches/day = exactly
/// two full cohort rounds. Small enough to finish in seconds, large
/// enough that all 128 connections are concurrently live on the one
/// event-loop thread.
const CONFIG_128W: &str = r#"
name = "process-workers-128w"
seed = 35

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1

[data]
days_base = 1
days_eval = 1
samples_per_day = 2048
teacher_seed = 3
label_noise = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 1024

[mode.sync]
workers = 128
local_batch = 8

[mode.gba]
workers = 128
local_batch = 8
iota = 3

[cluster]
workers = "remote"
worker_listen = "127.0.0.1:0"
"#;

fn log_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_TARGET_TMPDIR")).join("process-workers-logs");
    std::fs::create_dir_all(&dir).expect("creating worker log dir");
    dir
}

fn write_config(tag: &str, toml: &str) -> PathBuf {
    let path = log_dir().join(format!("{tag}.toml"));
    std::fs::write(&path, toml).expect("writing test config");
    path
}

/// One worker child. Killed (and reaped) on drop so a panicking test
/// never leaks processes.
struct WorkerProc {
    child: Child,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker(
    config: &Path,
    worker_id: usize,
    addr: &str,
    log_tag: &str,
    extra: &[&str],
) -> WorkerProc {
    let log = std::fs::File::create(log_dir().join(format!("{log_tag}-worker{worker_id}.log")))
        .expect("creating worker log file");
    let child = Command::new(BIN)
        .args([
            "worker",
            "--config",
            config.to_str().unwrap(),
            "--connect",
            addr,
            "--worker-id",
            &worker_id.to_string(),
        ])
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::from(log))
        .spawn()
        .expect("spawning worker child");
    WorkerProc { child }
}

/// Fingerprint a trained session: raw bits of every dense parameter and
/// embedding row, plus the control-plane counters.
struct DayFingerprint {
    dense_bits: Vec<Vec<u32>>,
    rows: Vec<(u64, Vec<u32>, u64, u32)>,
    applied: u64,
    dropped: u64,
    steps: u64,
    samples_trained: u64,
}

fn fingerprint(session: &TrainSession, stats: &gba::worker::session::DayStats) -> DayFingerprint {
    let ckpt = session.checkpoint();
    DayFingerprint {
        dense_bits: ckpt
            .dense
            .iter()
            .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
            .collect(),
        rows: ckpt
            .emb_rows
            .iter()
            .map(|(k, v, m)| {
                (*k, v.iter().map(|x| x.to_bits()).collect(), m.last_update_step, m.update_count)
            })
            .collect(),
        applied: stats.counters.applied_gradients,
        dropped: stats.counters.dropped_batches,
        steps: stats.counters.global_steps,
        samples_trained: stats.counters.samples_trained,
    }
}

fn assert_bit_identical(a: &DayFingerprint, b: &DayFingerprint) {
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.applied, b.applied);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.samples_trained, b.samples_trained);
    assert_eq!(a.dense_bits, b.dense_bits, "dense parameters diverged");
    assert_eq!(a.rows, b.rows, "embedding rows diverged");
}

/// Acceptance core: a day trained by a real `gba-train worker` child is
/// bit-identical to the same day trained by an in-thread worker.
#[test]
fn remote_worker_day_bit_identical_to_inproc() {
    // In-thread reference: same config, worker plane flipped.
    let mut cfg = ExperimentConfig::from_toml(CONFIG_1W).unwrap();
    cfg.cluster.workers = WorkerPlane::InProc;
    let inproc_session = TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default()).unwrap();
    assert!(inproc_session.worker_addr().is_none());
    let inproc_stats = inproc_session.train_day(0).unwrap();
    let inproc = fingerprint(&inproc_session, &inproc_stats);

    // Remote: the child derives data, model and seeds from the same
    // config file it is handed.
    let config = write_config("bitident", CONFIG_1W);
    let cfg = ExperimentConfig::from_toml(CONFIG_1W).unwrap();
    let session = TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default()).unwrap();
    let addr = session.worker_addr().expect("remote plane binds at build");
    let mut w0 = spawn_worker(&config, 0, &addr, "bitident", &[]);
    let stats = session.train_day(0).unwrap();
    let remote = fingerprint(&session, &stats);
    assert!(session.ps().quiescent());
    let n_batches = session.gen().batches_per_day(16) as u64;

    // Clean end of session: the explicit shutdown answers the worker's
    // pending BeginDay with the SessionOver farewell and the worker
    // exits 0 — a crashed front (no farewell, abrupt close) would
    // instead leave it exiting nonzero.
    session.shutdown_workers();
    drop(session);
    let status = w0.child.wait().expect("waiting for the worker child");
    assert!(status.success(), "worker did not exit cleanly after SessionOver: {status:?}");

    assert_bit_identical(&remote, &inproc);
    // Conservation on the clean day: every batch pushed, none reclaimed.
    assert_eq!(stats.failures, 0);
    assert_eq!(remote.applied + remote.dropped, n_batches);
}

/// SIGKILL one of four worker children mid-day: the front reclaims any
/// in-flight claim via `worker_reset`, the survivors finish the data
/// list, and the books balance — every issued batch was pushed
/// (applied or dropped) or reclaimed (a `failure`).
#[test]
fn killed_worker_process_reclaims_claim_and_day_completes() {
    let config = write_config("killworker", CONFIG_4W);
    let cfg = ExperimentConfig::from_toml(CONFIG_4W).unwrap();
    let session = TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default()).unwrap();
    let addr = session.worker_addr().unwrap();
    let mut workers: Vec<WorkerProc> = (0..4)
        .map(|w| spawn_worker(&config, w, &addr, "killworker", &["--batch-sleep-ms", "3"]))
        .collect();
    let before = session.eval_auc(1).unwrap();

    let stats = std::thread::scope(|scope| {
        let handle = scope.spawn(|| session.train_day(0));
        // Let the day get going, then SIGKILL worker 3 mid-flight. The
        // 3 ms per-batch sleep makes "mid-day" a ~0.8 s window.
        let t0 = Instant::now();
        while session.ps().counters().global_steps < 2 {
            assert!(t0.elapsed() < Duration::from_secs(60), "day never started");
            std::thread::sleep(Duration::from_millis(5));
        }
        workers[3].child.kill().expect("killing worker child");
        workers[3].child.wait().expect("reaping worker child");
        handle.join().expect("train_day thread panicked")
    })
    .expect("day failed after worker loss");

    assert!(session.ps().quiescent(), "claims or buffered grads leaked");
    let n_batches = session.gen().batches_per_day(16) as u64;
    // Conservation with re-issue: a reclaimed claim's batch goes back on
    // the data list and a survivor trains it, so the *whole* day resolves
    // as applied or dropped — no hole. (Whether the victim held a claim
    // at the instant SIGKILL landed is a race — failures/reissued may be
    // 0 or 1 — but coverage must be complete either way.)
    assert_eq!(
        stats.counters.applied_gradients + stats.counters.dropped_batches,
        n_batches,
        "a batch was lost: reclaim did not re-issue it"
    );
    assert_eq!(
        stats.reissued(),
        stats.failures,
        "every reclaimed claim must have been re-issued"
    );
    // Training still happened, on fewer shoulders.
    let after = session.eval_auc(1).unwrap();
    assert!(after > before, "auc did not improve: {before} -> {after}");

    // Later days continue on the survivors: the full complement is only
    // required for the session's first day, so the dead worker must not
    // stall day 1 (no replacement is launched).
    let stats1 = session.train_day(1).expect("day on 3 surviving workers");
    let n_batches = session.gen().batches_per_day(16) as u64;
    assert_eq!(
        stats1.counters.applied_gradients + stats1.counters.dropped_batches,
        n_batches
    );
    assert!(session.ps().quiescent());
}

/// ISSUE 7 acceptance: a 128-worker fleet day served end to end by ONE
/// front event-loop thread, bit-identical to the same day trained by
/// 128 in-thread workers.
///
/// The workers are in-test threads running [`run_worker_process`] — the
/// exact code path a `gba-train worker` child executes, over real TCP
/// through the real admission handshake — because 128 child processes
/// would buy no extra coverage of the front at 100× the spawn cost.
///
/// What makes the pin possible at this scale: sync's cohort barrier
/// fixes *which* batches each global step aggregates, and the control
/// plane's canonical (token, batch) flush order fixes the float
/// summation order — so the arbitrary order in which 128 racing
/// connections deliver their pushes cannot move a single bit.
#[test]
fn fleet_day_on_one_event_loop_bit_identical_to_inproc() {
    const W: usize = 128;

    // In-thread reference: same config, worker plane flipped.
    let mut cfg = ExperimentConfig::from_toml(CONFIG_128W).unwrap();
    cfg.cluster.workers = WorkerPlane::InProc;
    let inproc_session =
        TrainSession::new(cfg, ModeKind::Sync, SessionOptions::default()).unwrap();
    let inproc_stats = inproc_session.train_day(0).unwrap();
    let inproc = fingerprint(&inproc_session, &inproc_stats);

    let cfg = ExperimentConfig::from_toml(CONFIG_128W).unwrap();
    let session =
        TrainSession::new(cfg.clone(), ModeKind::Sync, SessionOptions::default()).unwrap();
    let addr = session.worker_addr().expect("remote plane binds at build");
    let (stats, remote) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..W)
            .map(|w| {
                let cfg = &cfg;
                let addr = addr.clone();
                scope.spawn(move || {
                    run_worker_process(cfg, ModeKind::Sync, w, &addr, WorkerProcOptions::default())
                })
            })
            .collect();
        let stats = session.train_day(0).expect("fleet day failed");
        let remote = fingerprint(&session, &stats);
        // SessionOver answers every worker's pending BeginDay; each
        // thread must come home having served exactly the one day.
        session.shutdown_workers();
        for (w, h) in handles.into_iter().enumerate() {
            let days = h
                .join()
                .expect("worker thread panicked")
                .unwrap_or_else(|e| panic!("worker {w} failed: {e:#}"));
            assert_eq!(days, 1, "worker {w} served {days} days");
        }
        (stats, remote)
    });

    assert!(session.ps().quiescent(), "claims or buffered grads leaked");
    assert_eq!(stats.failures, 0, "a worker was lost mid-day");
    let n_batches = session.gen().batches_per_day(8) as u64;
    assert_eq!(remote.applied + remote.dropped, n_batches);
    assert_bit_identical(&remote, &inproc);
}

/// A worker launched with the wrong `--mode` has a different local
/// batch; the `Hello` handshake rejects it and the day fails loudly.
#[test]
fn hello_mode_mismatch_fails_the_day_loudly() {
    let config = write_config("badmode", CONFIG_1W);
    let cfg = ExperimentConfig::from_toml(CONFIG_1W).unwrap();
    let session = TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default()).unwrap();
    let addr = session.worker_addr().unwrap();
    // sync's local_batch (32) != gba's (16): shape mismatch at Hello.
    let _w0 = spawn_worker(&config, 0, &addr, "badmode", &["--mode", "sync"]);
    let err = match session.train_day(0) {
        Err(e) => e,
        Ok(_) => panic!("a mis-moded worker was admitted"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("local_batch"), "unhelpful rejection: {msg}");
}
