//! Online serving plane, end to end (ISSUE 9).
//!
//! Pins the two contracts `gba-train serve` stands on:
//!
//!  * **Cache invalidation at the apply point** — a row a training apply
//!    just changed must never be served stale past the staleness window,
//!    on both transports: in-proc (`Arc<ShardedPs>` behind the
//!    `ReadShards` seam) and remote (`serve_shard` accept loops over
//!    TCP with `ReadHello` read companions).
//!  * **Snapshot consistency** — a served gather never observes a
//!    half-applied global batch: under a concurrent applier that moves
//!    every served key each step, every response is bit-identical to
//!    *one* applied step, and steps only move forward.
//!
//! Fixtures use `init_scale = 0.0` + `Sgd { lr: 1.0 }` + a gradient of
//! `-1.0` per key per step, so the exact row value IS the applied step
//! count — any torn or stale read shows up as a wrong number, not a
//! tolerance failure.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use gba::config::ServeConfig;
use gba::coordinator::modes::GbaPolicy;
use gba::embedding::EmbeddingConfig;
use gba::optim::Sgd;
use gba::runtime::{HostTensor, VariantDims};
use gba::serve::{serve_listener, RemoteReadShards, ServeClient, ServeFront};
use gba::shard::{ShardRouter, ShardedPs};
use gba::transport::codec::{GradPush, PullReply, ShardReply, ShardRequest};
use gba::transport::endpoint::{rpc, SocketConn};
use gba::transport::remote::serve_shard;
use gba::transport::supervisor::ShardSpawnSpec;

const DIM: usize = 4;
const DENSE_LEN: usize = 4;
const FIELDS: usize = 4;
const BATCH: usize = 4;

/// 16 keys, asserted to land on both PS shards so every gather is a
/// genuine cross-shard fan-out.
fn served_keys(ps: &ShardedPs) -> Vec<u64> {
    let keys: Vec<u64> = (0..(BATCH * FIELDS) as u64).collect();
    let shards: std::collections::HashSet<usize> =
        keys.iter().map(|&k| ps.shard_of_key(k)).collect();
    assert!(shards.len() > 1, "fixture keys all hash to one shard; widen the key range");
    keys
}

fn two_shard_ps() -> Arc<ShardedPs> {
    Arc::new(ShardedPs::with_shards(
        VariantDims { fields: FIELDS, emb_dim: DIM, hidden1: 8, hidden2: 4, mlp_in: 20 },
        vec![HostTensor { shape: vec![DENSE_LEN], data: vec![0.0; DENSE_LEN] }],
        EmbeddingConfig { dim: DIM, init_scale: 0.0, seed: 1, shards: 2 },
        Box::new(Sgd { lr: 1.0 }),
        Box::new(Sgd { lr: 1.0 }),
        Box::new(GbaPolicy::with_iota(1, 3)),
        2,
    ))
}

fn front_cfg(cache_rows: usize) -> ServeConfig {
    ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        cache_rows,
        cache_shards: 4,
        batch_window_us: 0,
        // Poll invalidations on every request: the staleness window is
        // zero, so a stale hit is a bug, not a config artifact.
        max_stale_ms: 0,
    }
}

/// One training step through the real pull/push seam: every key in
/// `keys` gets a `-1.0` gradient, so each flush adds exactly `+1.0`
/// (lr 1.0, one contributing worker) to every served row.
fn train_step(ps: &ShardedPs, keys: &[u64]) {
    let item = loop {
        match ps.pull(0) {
            PullReply::Work(item) => break item,
            PullReply::Wait => std::thread::yield_now(),
            PullReply::EndOfData => panic!("fixture ran out of batches"),
        }
    };
    ps.push(GradPush {
        worker: 0,
        token: item.token,
        dense: vec![HostTensor { shape: vec![DENSE_LEN], data: vec![0.0; DENSE_LEN] }],
        emb: keys.iter().map(|&k| (k, vec![-1.0; DIM])).collect(),
        n_samples: 1,
        loss: 0.0,
    });
}

#[test]
fn inproc_apply_invalidates_cached_rows() {
    let ps = two_shard_ps();
    let keys = served_keys(&ps);
    let cached = ServeFront::new(Box::new(ps.clone()), front_cfg(1024));
    let direct = ServeFront::new(Box::new(ps.clone()), front_cfg(0));

    let before = cached.gather(&keys, BATCH, FIELDS).unwrap();
    assert_eq!(before.shape, vec![BATCH, FIELDS, DIM]);
    assert!(before.data.iter().all(|&v| v == 0.0), "untrained rows must be zero");
    let again = cached.gather(&keys, BATCH, FIELDS).unwrap();
    assert_eq!(again.data, before.data);
    assert!(
        cached.stats_snapshot().cache_hits >= keys.len() as u64,
        "second gather should be served from the hot-key cache"
    );

    // A training day moves every served key underneath the front.
    ps.set_day(0, 8);
    train_step(&ps, &keys);

    let fresh = direct.gather(&keys, BATCH, FIELDS).unwrap();
    assert!(fresh.data.iter().all(|&v| v == 1.0), "apply must land before an uncached read");
    let served = cached.gather(&keys, BATCH, FIELDS).unwrap();
    assert_eq!(
        served.data, fresh.data,
        "cached front served a stale row past the invalidation point"
    );
    let s = cached.stats_snapshot();
    assert!(s.cache_evictions >= keys.len() as u64, "applied keys must be evicted, got {s:?}");
}

/// The clock-eviction contract (ISSUE 10 satellite): a hot key set that
/// keeps getting re-gathered must survive waves of one-shot cold keys.
/// The pre-clock cache flushed a whole lock-shard every time it filled,
/// so any sustained cold churn wiped the Zipfian head and every hot
/// re-gather missed; second-chance eviction keeps the referenced head
/// resident and evicts only the unreferenced churn.
#[test]
fn hot_keys_survive_cold_churn_under_clock_eviction() {
    let ps = two_shard_ps();
    let hot = served_keys(&ps);
    // 128 rows over 4 cache shards = 32 rows per shard: even in the
    // worst hash layout (all 16 hot keys on one cache shard) a churn
    // wave's clock sweep cannot lap a re-referenced hot entry.
    let front = ServeFront::new(Box::new(ps.clone()), front_cfg(128));

    // Warm the hot set: first gather fills, second marks referenced.
    front.gather(&hot, BATCH, FIELDS).unwrap();
    front.gather(&hot, BATCH, FIELDS).unwrap();

    let waves = 16usize;
    let mut hot_hits_expected = 0u64;
    let hits_at_start = front.stats_snapshot().cache_hits;
    for wave in 0..waves {
        // A wave of one-shot cold keys, disjoint from the hot set and
        // from every other wave — enough total churn (16 * 16 keys) to
        // overflow each cache shard several times.
        let cold: Vec<u64> =
            (0..(BATCH * FIELDS) as u64).map(|i| 1_000 + (wave as u64) * 100 + i).collect();
        front.gather(&cold, BATCH, FIELDS).unwrap();
        // The hot set is re-gathered between waves (that is what "hot"
        // means); every one of these must be a cache hit.
        front.gather(&hot, BATCH, FIELDS).unwrap();
        hot_hits_expected += hot.len() as u64;
    }
    let s = front.stats_snapshot();
    assert!(
        s.cache_hits - hits_at_start >= hot_hits_expected,
        "hot keys fell out of the cache under cold churn: {} hits across {waves} waves, \
         wanted at least {hot_hits_expected} ({s:?})",
        s.cache_hits - hits_at_start,
    );
    assert!(s.cache_evictions > 0, "churn never pressured the cache; the test is vacuous ({s:?})");
}

/// Boot one `serve_shard` accept loop and return its address plus the
/// primary connection that anchors the generation read companions
/// attach to (and that raw `Apply` RPCs drive).
fn boot_shard(index: usize) -> (String, SocketConn) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let spec = ShardSpawnSpec {
            index,
            ranges: vec![(0, DENSE_LEN)],
            emb_cfg: EmbeddingConfig { dim: DIM, init_scale: 0.0, seed: 1, shards: 2 },
            opt_dense: Box::new(Sgd { lr: 1.0 }),
            opt_emb: Box::new(Sgd { lr: 1.0 }),
            addr: None,
            apply_threads: 1,
        };
        let init = [HostTensor { shape: vec![DENSE_LEN], data: vec![0.0; DENSE_LEN] }];
        let _ = serve_shard(listener, spec, &init);
    });
    let mut primary = SocketConn::new(TcpStream::connect(&addr).unwrap());
    match rpc(&mut primary, ShardRequest::Ping).unwrap() {
        ShardReply::Ok => {}
        other => panic!("shard {index}: Ping rejected: {other:?}"),
    }
    (addr, primary)
}

/// Apply `opt_step` on BOTH shard servers (keys routed the same way the
/// serve front routes gathers), so the fleet agrees on the step again
/// once the round of RPCs completes.
fn remote_apply(primaries: &mut [SocketConn], keys: &[u64], opt_step: u64) {
    let router = ShardRouter::new(primaries.len());
    for (s, conn) in primaries.iter_mut().enumerate() {
        let emb: Vec<(u64, Vec<f32>, u32)> = keys
            .iter()
            .filter(|&&k| router.shard_of_key(k) == s)
            .map(|&k| (k, vec![-1.0; DIM], 1))
            .collect();
        let reply = rpc(
            conn,
            ShardRequest::Apply { opt_step, dense: vec![vec![0.0; DENSE_LEN]], emb },
        )
        .unwrap();
        assert!(matches!(reply, ShardReply::Ok), "shard {s}: apply rejected");
    }
}

#[test]
fn remote_apply_invalidates_cached_rows() {
    let (addr0, prim0) = boot_shard(0);
    let (addr1, prim1) = boot_shard(1);
    let mut primaries = [prim0, prim1];
    let addrs = [addr0, addr1];

    let reads = RemoteReadShards::connect(&addrs, DIM, Duration::from_secs(10)).unwrap();
    let front = ServeFront::new(Box::new(reads), front_cfg(1024));
    let keys: Vec<u64> = (0..(BATCH * FIELDS) as u64).collect();

    let before = front.gather(&keys, BATCH, FIELDS).unwrap();
    assert!(before.data.iter().all(|&v| v == 0.0));
    front.gather(&keys, BATCH, FIELDS).unwrap();
    assert!(front.stats_snapshot().cache_hits >= keys.len() as u64);

    remote_apply(&mut primaries, &keys, 1);
    let served = front.gather(&keys, BATCH, FIELDS).unwrap();
    assert!(
        served.data.iter().all(|&v| v == 1.0),
        "remote front served a stale row after a raw shard apply"
    );

    remote_apply(&mut primaries, &keys, 2);
    let served = front.gather(&keys, BATCH, FIELDS).unwrap();
    assert!(served.data.iter().all(|&v| v == 2.0));
    assert!(front.stats_snapshot().cache_evictions >= 2 * keys.len() as u64);
}

#[test]
fn gathers_are_bit_identical_to_one_applied_step_under_concurrent_applies() {
    const STEPS: usize = 120;
    let ps = two_shard_ps();
    let keys = served_keys(&ps);
    // cache off: every gather is a live cross-shard snapshot fan-out.
    let front = ServeFront::new(Box::new(ps.clone()), front_cfg(0));
    ps.set_day(0, STEPS);

    let applier = {
        let ps = ps.clone();
        let keys = keys.clone();
        std::thread::spawn(move || {
            for _ in 0..STEPS {
                train_step(&ps, &keys);
            }
        })
    };

    let mut last = 0.0f32;
    while !applier.is_finished() {
        let t = front.gather(&keys, BATCH, FIELDS).unwrap();
        let v = t.data[0];
        assert!(
            t.data.iter().all(|&x| x.to_bits() == v.to_bits()),
            "torn read: a gather mixed rows from two applied steps: {:?}",
            &t.data[..DIM.min(t.data.len())]
        );
        assert_eq!(v.fract(), 0.0, "served value {v} is not a whole applied step");
        assert!(v >= last, "served step went backwards: {last} -> {v}");
        last = v;
    }
    applier.join().unwrap();

    let done = front.gather(&keys, BATCH, FIELDS).unwrap();
    assert!(
        done.data.iter().all(|&x| x == STEPS as f32),
        "final gather must see every applied step"
    );
}

#[test]
fn concurrent_tcp_clients_coalesce_into_shared_rounds() {
    let ps = two_shard_ps();
    let keys = served_keys(&ps);
    let front = Arc::new(ServeFront::new(
        Box::new(ps.clone()),
        ServeConfig {
            listen: "127.0.0.1:0".to_string(),
            cache_rows: 0,
            cache_shards: 4,
            batch_window_us: 3_000,
            max_stale_ms: 60_000,
        },
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = serve_listener(front.clone(), listener).unwrap();

    let n_clients = 4;
    let per_client = 8;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let keys = &keys;
            let addr = addr.to_string();
            scope.spawn(move || {
                let mut client = ServeClient::connect(&addr, Duration::from_secs(5)).unwrap();
                for _ in 0..per_client {
                    let t = client.gather(&keys[c * 2..c * 2 + 8], 2, FIELDS).unwrap();
                    assert_eq!(t.shape, vec![2, FIELDS, DIM]);
                }
            });
        }
    });

    let s = front.stats_snapshot();
    assert_eq!(s.requests, (n_clients * per_client) as u64);
    assert!(
        s.rounds < s.requests,
        "collection window never coalesced concurrent misses: {s:?}"
    );
}
