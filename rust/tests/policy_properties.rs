//! Property-based tests on the coordination policies (the paper's §4.1
//! invariants), driven by random worker interleavings.
//!
//! The harness mimics a set of workers with in-flight batches: at each step
//! it randomly either lets some worker pull (if not gated) or lets a random
//! in-flight batch finish and push. Policies must uphold their invariants
//! under ANY such interleaving — this is the GBA-correctness core.

use gba::config::{ModeConfig, ModeKind};
use gba::coordinator::modes::{make_policy, GbaPolicy, HopBsPolicy, SyncPolicy};
use gba::coordinator::{DecayStrategy, ModePolicy, PullDecision, PushAction};
use gba::staleness::{
    make_staleness, GapAwareStaleness, StalenessConfig, StalenessPolicy, StalenessPolicyKind,
};
use gba::util::prop;
use gba::util::rng::Pcg64;

/// Random interleaving driver. Returns per-flush records:
/// (global step k at flush, tokens, weights).
struct Harness {
    policy: Box<dyn ModePolicy>,
    n_workers: usize,
    /// tokens in flight per worker
    inflight: Vec<Vec<u64>>,
    buffer: Vec<u64>,
    pub flushes: Vec<(u64, Vec<u64>, Vec<f32>)>,
    pub dropped_on_push: u64,
    pub pulls: Vec<u64>,
}

impl Harness {
    fn new(policy: Box<dyn ModePolicy>, n_workers: usize) -> Self {
        Harness {
            policy,
            n_workers,
            inflight: vec![Vec::new(); n_workers],
            buffer: Vec::new(),
            flushes: Vec::new(),
            dropped_on_push: 0,
            pulls: Vec::new(),
        }
    }

    fn total_inflight(&self) -> usize {
        self.inflight.iter().map(|v| v.len()).sum()
    }

    /// One random action; returns false if nothing was possible.
    fn step(&mut self, rng: &mut Pcg64) -> bool {
        let w = rng.gen_range(self.n_workers as u64) as usize;
        let do_pull = rng.bernoulli(0.55) || self.total_inflight() == 0;
        if do_pull {
            match self.policy.on_pull(w) {
                PullDecision::Token(t) => {
                    self.inflight[w].push(t);
                    self.pulls.push(t);
                    true
                }
                PullDecision::Wait => self.push_random(rng),
            }
        } else {
            self.push_random(rng)
        }
    }

    fn push_random(&mut self, rng: &mut Pcg64) -> bool {
        let candidates: Vec<usize> =
            (0..self.n_workers).filter(|&w| !self.inflight[w].is_empty()).collect();
        if candidates.is_empty() {
            return false;
        }
        let w = *rng.choose(&candidates);
        // Pushes may complete out of order within a worker too.
        let idx = rng.gen_range(self.inflight[w].len() as u64) as usize;
        let token = self.inflight[w].remove(idx);
        match self.policy.on_push(w, token) {
            PushAction::Drop => {
                self.dropped_on_push += 1;
            }
            PushAction::Buffer => self.buffer.push(token),
            PushAction::FlushNow => {
                self.buffer.push(token);
                let k = self.policy.global_step();
                let spec = self.policy.flush_spec(&self.buffer);
                self.flushes.push((k, self.buffer.clone(), spec.weights.clone()));
                self.buffer.clear();
                self.policy.on_applied();
            }
        }
        true
    }
}

#[test]
fn gba_tokens_ascending_with_multiplicity_m() {
    prop::check("gba token list", 40, |rng| {
        let m = 1 + rng.gen_range(8) as usize;
        let workers = 1 + rng.gen_range(6) as usize;
        let mut h = Harness::new(Box::new(GbaPolicy::with_iota(m, 3)), workers);
        for _ in 0..400 {
            h.step(rng);
        }
        // Pull order IS the token list: t_i = floor(i / M).
        for (i, &t) in h.pulls.iter().enumerate() {
            assert_eq!(t, (i / m) as u64, "i={i} m={m}");
        }
    });
}

#[test]
fn gba_flushes_exactly_m_and_decays_by_iota() {
    prop::check("gba flush invariants", 40, |rng| {
        let m = 1 + rng.gen_range(6) as usize;
        let iota = rng.gen_range(4);
        let workers = 1 + rng.gen_range(8) as usize;
        let mut h = Harness::new(Box::new(GbaPolicy::with_iota(m, iota)), workers);
        for _ in 0..600 {
            h.step(rng);
        }
        assert!(h.dropped_on_push == 0, "GBA never drops at push time");
        for (k, tokens, weights) in &h.flushes {
            assert_eq!(tokens.len(), m, "buffer capacity is exactly M");
            for (&t, &w) in tokens.iter().zip(weights) {
                let stale = k.saturating_sub(t);
                if stale > iota {
                    assert_eq!(w, 0.0, "stale grad (k={k}, t={t}) must be dropped");
                } else {
                    assert_eq!(w, 1.0, "fresh grad (k={k}, t={t}) must be kept");
                }
            }
        }
    });
}

#[test]
fn gradient_conservation_all_modes() {
    // Every pushed gradient is exactly once: admitted (weight > 0),
    // decayed-out (weight 0), or dropped at push. Nothing is lost or
    // double-counted.
    prop::check("conservation", 30, |rng| {
        let workers = 2 + rng.gen_range(6) as usize;
        let mc = ModeConfig {
            workers,
            local_batch: 8,
            iota: rng.gen_range(4),
            bound: 1 + rng.gen_range(3),
            aggregate: 1 + rng.gen_range(5) as usize,
            backup: rng.gen_range(workers as u64 - 1) as usize,
            m_override: None,
        };
        for kind in ModeKind::ALL {
            let mut h = Harness::new(make_policy(kind, &mc, 4), workers);
            let mut actions = 0;
            for _ in 0..500 {
                if h.step(rng) {
                    actions += 1;
                }
            }
            assert!(actions > 0);
            let pushed = h.pulls.len() - h.total_inflight() - h.buffer.len();
            let flushed: usize = h.flushes.iter().map(|(_, t, _)| t.len()).sum();
            assert_eq!(
                pushed,
                flushed + h.dropped_on_push as usize,
                "mode {kind:?}: pushed {pushed} != flushed {flushed} + dropped {}",
                h.dropped_on_push
            );
        }
    });
}

#[test]
fn sync_cohorts_are_exact() {
    prop::check("sync cohorts", 30, |rng| {
        let n = 2 + rng.gen_range(6) as usize;
        let mut h = Harness::new(Box::new(SyncPolicy::new(n)), n);
        for _ in 0..400 {
            h.step(rng);
        }
        for (i, (k, tokens, weights)) in h.flushes.iter().enumerate() {
            assert_eq!(tokens.len(), n, "sync flush has one grad per worker");
            assert!(tokens.iter().all(|&t| t == i as u64), "all tokens equal the step");
            assert_eq!(*k, i as u64);
            assert!(weights.iter().all(|&w| w == 1.0), "sync never drops");
        }
        assert_eq!(h.dropped_on_push, 0);
    });
}

#[test]
fn hop_bs_clock_gap_never_exceeds_bound() {
    prop::check("hop-bs bound", 40, |rng| {
        let n = 2 + rng.gen_range(5) as usize;
        let bound = 1 + rng.gen_range(3);
        let mut policy = HopBsPolicy::new(n, bound);
        // Track worker completion counts externally.
        let mut clock = vec![0u64; n];
        let mut inflight: Vec<Vec<u64>> = vec![Vec::new(); n];
        for _ in 0..500 {
            let w = rng.gen_range(n as u64) as usize;
            if rng.bernoulli(0.55) {
                if let PullDecision::Token(t) = policy.on_pull(w) {
                    inflight[w].push(t);
                }
            } else {
                let candidates: Vec<usize> =
                    (0..n).filter(|&i| !inflight[i].is_empty()).collect();
                if let Some(&w2) = candidates.first() {
                    let t = inflight[w2].pop().unwrap();
                    let _ = policy.on_push(w2, t);
                    policy.flush_spec(&[t]);
                    policy.on_applied();
                    clock[w2] += 1;
                    let min = *clock.iter().min().unwrap();
                    let max = *clock.iter().max().unwrap();
                    assert!(
                        max - min <= bound,
                        "SSP violated: clocks {clock:?} bound {bound}"
                    );
                }
            }
        }
    });
}

#[test]
fn hop_bw_admits_exactly_quorum_per_step() {
    prop::check("hop-bw quorum", 30, |rng| {
        let n = 3 + rng.gen_range(5) as usize;
        let b3 = 1 + rng.gen_range((n - 2) as u64) as usize;
        let mc = ModeConfig { workers: n, local_batch: 8, iota: 3, bound: 2, aggregate: 1, backup: b3, m_override: None };
        let mut h = Harness::new(make_policy(ModeKind::HopBw, &mc, 4), n);
        for _ in 0..600 {
            h.step(rng);
        }
        for (k, tokens, _) in &h.flushes {
            assert_eq!(tokens.len(), n - b3, "quorum is N - b3");
            assert!(tokens.iter().all(|&t| t == *k), "cohort tokens match step");
        }
    });
}

#[test]
fn decay_strategies_are_monotone_in_staleness() {
    prop::check("decay monotone", 50, |rng| {
        let strategies = [
            DecayStrategy::Threshold { iota: rng.gen_range(5) },
            DecayStrategy::Linear { iota: 1 + rng.gen_range(5) },
            DecayStrategy::Exponential { alpha: 0.3 + 0.6 * rng.next_f32() },
        ];
        let k = 50 + rng.gen_range(50);
        for s in strategies {
            let mut prev = f32::INFINITY;
            for stale in 0..20u64 {
                let w = s.weight(k - stale, k);
                assert!((0.0..=1.0).contains(&w));
                assert!(w <= prev, "{s:?} not monotone at staleness {stale}");
                prev = w;
            }
            assert_eq!(s.weight(k, k), 1.0, "{s:?} fresh weight must be 1");
        }
    });
}

// --- staleness-policy invariants (ISSUE 10) ---------------------------------
//
// The `StalenessPolicy` seam reweights the mode policy's flush weights in
// place. Random harness interleavings above produce the recorded flush
// sequences; these properties hold for every policy on every recording.

/// Record a random GBA run and return (pull order, flushes).
fn record_gba_run(rng: &mut Pcg64) -> (Vec<u64>, Vec<(u64, Vec<u64>, Vec<f32>)>) {
    let m = 1 + rng.gen_range(6) as usize;
    let iota = rng.gen_range(5);
    let workers = 2 + rng.gen_range(6) as usize;
    let mut h = Harness::new(Box::new(GbaPolicy::with_iota(m, iota)), workers);
    for _ in 0..500 {
        h.step(rng);
    }
    (h.pulls, h.flushes)
}

/// Replay a recording through one staleness policy: issue every pulled
/// token in order (feeding random update norms in between, as the apply
/// loop would), then reweight each recorded flush. Returns the
/// reweighted flushes paired with their recorded base weights.
fn replay(
    rng: &mut Pcg64,
    policy: &mut dyn StalenessPolicy,
    pulls: &[u64],
    flushes: &[(u64, Vec<u64>, Vec<f32>)],
) -> Vec<(Vec<f32>, Vec<f32>)> {
    for &t in pulls {
        policy.on_issue(t);
        if rng.bernoulli(0.5) {
            // Hostile norms too: zero, huge, ordinary.
            let norm = match rng.gen_range(4) {
                0 => 0.0,
                1 => 1e9,
                _ => rng.next_f32() as f64,
            };
            policy.on_update_norm(norm);
        }
    }
    flushes
        .iter()
        .map(|(k, tokens, base)| {
            let mut w = base.clone();
            policy.reweight(*k, tokens, &mut w);
            (base.clone(), w)
        })
        .collect()
}

fn random_staleness_cfg(rng: &mut Pcg64, kind: StalenessPolicyKind) -> StalenessConfig {
    let min = 1 + rng.gen_range(4);
    StalenessConfig {
        policy: kind,
        gap_scale: 0.1 + rng.next_f32() as f64 * 4.0,
        abs_bound_min: min,
        abs_bound_max: min + rng.gen_range(12),
        abs_adapt_rate: (rng.next_f32() as f64).clamp(0.05, 1.0),
    }
}

#[test]
fn staleness_reweights_stay_in_unit_interval_and_never_raise() {
    prop::check("staleness weight range", 30, |rng| {
        let (pulls, flushes) = record_gba_run(rng);
        for kind in StalenessPolicyKind::ALL {
            let cfg = random_staleness_cfg(rng, kind);
            let mut policy = make_staleness(&cfg);
            for (base, w) in replay(rng, policy.as_mut(), &pulls, &flushes) {
                for (&b, &x) in base.iter().zip(&w) {
                    assert!(
                        (0.0..=1.0).contains(&x),
                        "{kind:?}: weight {x} outside [0,1] (base {b})"
                    );
                    assert!(x <= b, "{kind:?}: reweight raised {b} to {x}");
                }
            }
        }
    });
}

#[test]
fn gba_staleness_is_bitwise_identity_on_recorded_flushes() {
    // The default policy's contract: `staleness_policy = "gba"` must be
    // indistinguishable — bit for bit — from the pre-seam decay.
    prop::check("gba staleness identity", 30, |rng| {
        let (pulls, flushes) = record_gba_run(rng);
        let cfg = StalenessConfig::default();
        let mut policy = make_staleness(&cfg);
        for (base, w) in replay(rng, policy.as_mut(), &pulls, &flushes) {
            for (b, x) in base.iter().zip(&w) {
                assert_eq!(b.to_bits(), x.to_bits(), "gba identity broken: {b} -> {x}");
            }
        }
    });
}

#[test]
fn gap_aware_weight_monotone_nonincreasing_in_gap() {
    prop::check("gap_aware monotone", 40, |rng| {
        let mut policy = GapAwareStaleness::new(0.1 + rng.next_f32() as f64 * 4.0);
        // Token i is issued after i updates have landed, so in one flush
        // at step n the gap strictly decreases with i — the reweighted
        // weight must be non-decreasing in i (older = never weighted more).
        let n = 4 + rng.gen_range(12);
        for t in 0..n {
            policy.on_issue(t);
            policy.on_update_norm(0.25 + rng.next_f32() as f64);
        }
        let tokens: Vec<u64> = (0..n).collect();
        let mut w = vec![1.0f32; tokens.len()];
        policy.reweight(n, &tokens, &mut w);
        for pair in w.windows(2) {
            assert!(
                pair[0] <= pair[1],
                "older token outweighed a fresher one: {w:?}"
            );
        }
        assert!(w.iter().all(|&x| (0.0..=1.0).contains(&x)), "{w:?}");
    });
}

#[test]
fn abs_bound_stays_within_clamp_on_hostile_histograms() {
    prop::check("abs bound clamp", 30, |rng| {
        let (pulls, flushes) = record_gba_run(rng);
        let cfg = random_staleness_cfg(rng, StalenessPolicyKind::Abs);
        let mut policy = make_staleness(&cfg);
        for &t in &pulls {
            policy.on_issue(t);
        }
        for (k, tokens, base) in &flushes {
            let mut w = base.clone();
            // Hostile step offsets push deep staleness into the histogram.
            let k = k + rng.gen_range(1000);
            policy.reweight(k, tokens, &mut w);
            let bound = policy.current_bound().expect("abs always reports a bound");
            assert!(
                (cfg.abs_bound_min as f64..=cfg.abs_bound_max as f64).contains(&bound),
                "bound {bound} escaped clamp [{}, {}]",
                cfg.abs_bound_min,
                cfg.abs_bound_max
            );
        }
    });
}

#[test]
fn worker_reset_never_corrupts_policies() {
    prop::check("reset safety", 30, |rng| {
        let workers = 2 + rng.gen_range(5) as usize;
        let mc = ModeConfig {
            workers,
            local_batch: 8,
            iota: 2,
            bound: 2,
            aggregate: 3,
            backup: 1.min(workers - 2),
            m_override: None,
        };
        for kind in ModeKind::ALL {
            let mut h = Harness::new(make_policy(kind, &mc, 4), workers);
            for _ in 0..300 {
                if rng.bernoulli(0.1) {
                    // Random worker dies: its in-flight tokens vanish.
                    let w = rng.gen_range(workers as u64) as usize;
                    h.inflight[w].clear();
                    h.policy.on_worker_reset(w);
                } else {
                    h.step(rng);
                }
            }
            // Policy still functional: progress is possible — either some
            // worker can pull, or in-flight work exists whose push will
            // advance the system.
            let can_push = h.total_inflight() > 0;
            let can_pull = (0..workers)
                .any(|w| matches!(h.policy.on_pull(w), PullDecision::Token(_)));
            assert!(can_pull || can_push, "mode {kind:?} deadlocked after resets");
        }
    });
}
