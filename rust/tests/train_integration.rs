//! Integration tests across the whole stack:
//!
//! 1. The PJRT artifact (python-authored, pallas-lowered) and the native
//!    Rust model must produce identical numerics — this pins L1+L2 to L3.
//! 2. A full training session on the PJRT backend must train (AUC rises),
//!    proving the three layers compose on the request path.
//!
//! Both require `make artifacts` (tiny variant); they skip gracefully if
//! artifacts are absent so `cargo test` works in a fresh checkout.

use gba::config::{ExperimentConfig, ModeKind};
use gba::model::NativeModel;
use gba::runtime::{EnginePool, HostTensor, Manifest};
use gba::util::rng::Pcg64;
use gba::worker::session::{SessionOptions, TrainSession};
use gba::worker::BackendKind;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn rand_tensor(rng: &mut Pcg64, shape: Vec<usize>, scale: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::new(shape, (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()).unwrap()
}

#[test]
fn pjrt_and_native_numerics_agree() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let dims = manifest.dims("tiny").unwrap();
    let native = NativeModel::new(dims);
    let pool = EnginePool::start(&manifest, "tiny", 1).unwrap();
    let h = pool.handle();

    for (seed, batch) in [(1u64, 8usize), (2, 32), (3, 8)] {
        let mut rng = Pcg64::seeded(seed);
        let emb = rand_tensor(&mut rng, vec![batch, dims.fields, dims.emb_dim], 0.4);
        let params: Vec<HostTensor> =
            dims.param_shapes().into_iter().map(|s| rand_tensor(&mut rng, s, 0.3)).collect();
        let labels: Vec<f32> =
            (0..batch).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();

        let a = native.train_step(&emb, &params, &labels);
        let b = h.train_step(batch, emb.clone(), params.clone(), labels.clone()).unwrap();

        assert!((a.loss - b.loss).abs() < 1e-4, "loss {} vs {}", a.loss, b.loss);
        for (x, y) in a.logits.iter().zip(&b.logits) {
            assert!((x - y).abs() < 1e-4, "logit {x} vs {y}");
        }
        for (x, y) in a.d_emb.data.iter().zip(&b.d_emb.data) {
            assert!((x - y).abs() < 1e-4, "d_emb {x} vs {y}");
        }
        for (ga, gb) in a.d_dense.iter().zip(&b.d_dense) {
            assert_eq!(ga.shape, gb.shape);
            for (x, y) in ga.data.iter().zip(&gb.data) {
                assert!((x - y).abs() < 2e-4, "dense grad {x} vs {y}");
            }
        }

        // predict parity too
        let pa = native.predict(&emb, &params);
        let pb = h.predict(batch, emb, params).unwrap();
        for (x, y) in pa.iter().zip(&pb) {
            assert!((x - y).abs() < 1e-4);
        }
    }
    pool.shutdown();
}

fn pjrt_cfg() -> ExperimentConfig {
    ExperimentConfig::from_toml(
        r#"
name = "pjrt-session-test"
seed = 21
[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 32
hidden2 = 16
vocab_size = 1000
zipf_s = 1.1
[data]
days_base = 1
days_eval = 1
samples_per_day = 1024
teacher_seed = 5
label_noise = 0.02
[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 32
eval_samples = 512
[mode.sync]
workers = 2
local_batch = 32
[mode.gba]
workers = 4
local_batch = 8
iota = 3
"#,
    )
    .unwrap()
}

#[test]
fn pjrt_backend_trains_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = SessionOptions {
        backend: BackendKind::Pjrt,
        artifacts_dir: dir,
        engine_threads: 2,
        ..SessionOptions::default()
    };
    let s = TrainSession::new(pjrt_cfg(), ModeKind::Gba, opts).unwrap();
    let before = s.eval_auc(1).unwrap();
    s.train_day(0).unwrap();
    let after = s.eval_auc(1).unwrap();
    assert!(after > before + 0.03, "pjrt auc {before} -> {after}");
    assert!(s.ps().counters().global_steps > 0);
}

#[test]
fn native_and_pjrt_sessions_learn_equivalently() {
    // Not bit-identical (thread interleaving differs) but both backends
    // must reach similar AUC from the same config.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let native = TrainSession::new(pjrt_cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
    native.train_day(0).unwrap();
    let a_native = native.eval_auc(1).unwrap();

    let opts = SessionOptions {
        backend: BackendKind::Pjrt,
        artifacts_dir: dir,
        engine_threads: 2,
        ..SessionOptions::default()
    };
    let pjrt = TrainSession::new(pjrt_cfg(), ModeKind::Sync, opts).unwrap();
    pjrt.train_day(0).unwrap();
    let a_pjrt = pjrt.eval_auc(1).unwrap();

    assert!(
        (a_native - a_pjrt).abs() < 0.05,
        "backend divergence: native {a_native} vs pjrt {a_pjrt}"
    );
}
